//! Minimal JSON parser + emitter.
//!
//! serde is not in the offline crate set; the only JSON this project
//! handles is the artifact manifest written by `python/compile/aot.py`
//! and small result summaries, so a compact recursive-descent parser is
//! the right tool.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize (compact).
    pub fn emit(&self) -> String {
        let mut out = String::new();
        self.emit_into(&mut out);
        out
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_string(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_string(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience builder for objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.emit()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn emit_escapes_roundtrip() {
        let s = Json::Str("line\n\"quoted\"\\x".to_string());
        let v = Json::parse(&s.emit()).unwrap();
        assert_eq!(v, s);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo ∑\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∑"));
        assert_eq!(Json::parse(&v.emit()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "tru", "\"abc", "1.2.3", "{\"a\" 1}", "[1 2]", "{} x"] {
            assert!(Json::parse(s).is_err(), "{s} should fail");
        }
    }

    #[test]
    fn integer_emit_is_integral() {
        assert_eq!(Json::Num(42.0).emit(), "42");
        assert_eq!(Json::Num(-3.0).emit(), "-3");
        assert_eq!(Json::Num(1.5).emit(), "1.5");
    }

    #[test]
    fn obj_builder_and_access() {
        let v = obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("z".into()))]);
        assert_eq!(v.get("x").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("y").unwrap().as_str(), Some("z"));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
