//! Tiny property-based testing harness (offline substitute for
//! proptest). Provides seeded case generation, a configurable number of
//! cases, and first-failure reporting with the failing seed so a case
//! can be replayed deterministically.
//!
//! Usage:
//! ```ignore
//! prop::check("partition covers", 200, |g| {
//!     let n = g.usize_in(1, 1000);
//!     let p = g.usize_in(1, 16);
//!     let parts = partition(n, p);
//!     prop::assert_that(parts.concat().len() == n, "cover")
//! });
//! ```

use super::rng::Xoshiro256;

/// Per-case generator handle.
pub struct Gen {
    pub rng: Xoshiro256,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(lo <= hi_inclusive);
        lo + self.rng.gen_index(hi_inclusive - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_index(xs.len())]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }
}

/// Run `cases` randomized cases of `body`. The body returns
/// `Result<(), String>`; the first failure panics with the case seed.
/// Base seed can be overridden via `DSO_PROP_SEED` for replay;
/// `DSO_PROP_CASES` scales the case count.
pub fn check(name: &str, cases: usize, mut body: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base_seed = std::env::var("DSO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xD50_2014);
    let cases = std::env::var("DSO_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(cases);
    let mut root = Xoshiro256::new(base_seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen { rng: Xoshiro256::new(case_seed), case_seed };
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with DSO_PROP_SEED={base_seed}, case seed {case_seed}): {msg}"
            );
        }
    }
}

/// Helper for readable assertions inside property bodies.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always true", 50, |g| {
            n += 1;
            let x = g.usize_in(0, 10);
            assert_that(x <= 10, "bound")
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            let x = g.usize_in(0, 100);
            assert_that(x > 1000, "impossible")
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen ranges", 100, |g| {
            let u = g.usize_in(3, 7);
            assert_that((3..=7).contains(&u), format!("usize {u}"))?;
            let f = g.f64_in(-1.0, 1.0);
            assert_that((-1.0..1.0).contains(&f), format!("f64 {f}"))?;
            let v = g.vec_f32(5, 0.0, 2.0);
            assert_that(v.len() == 5 && v.iter().all(|&x| (0.0..2.0).contains(&x)), "vec")
        });
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(assert_close(1.0, 2.0, 1e-6, "x").is_err());
    }

    #[test]
    fn pick_returns_member() {
        check("pick", 50, |g| {
            let xs = [1, 5, 9];
            let p = *g.pick(&xs);
            assert_that(xs.contains(&p), "member")
        });
    }
}
