//! Minimal leveled logger (log crate facade is available but a backend
//! is not, so we provide our own tiny sink with timestamps relative to
//! process start). Controlled by `DSO_LOG` = error|warn|info|debug|trace.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info default
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("DSO_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:>9.3}s {}] {args}", l.tag());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_gating() {
        init();
        let old = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(old);
    }

    #[test]
    fn ordering_of_levels() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }
}
