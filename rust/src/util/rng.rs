//! Deterministic pseudo-random number generation.
//!
//! The offline crate set does not include `rand`, so DSO carries its own
//! small, well-tested PRNG stack:
//!
//! * [`SplitMix64`] — used for seeding / stream splitting (Steele et al.).
//! * [`Xoshiro256`] — xoshiro256**, the workhorse generator (Blackman &
//!   Vigna). Fast, 256-bit state, passes BigCrush.
//!
//! Every stochastic component in the library (data generators, samplers,
//! the DSO workers) takes an explicit seed so that runs are reproducible
//! and the serializability tests can replay a distributed run exactly.

/// SplitMix64: tiny generator used to expand a `u64` seed into streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn split(&mut self, tag: u64) -> Xoshiro256 {
        let base = self.next_u64();
        Xoshiro256::new(base ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform in `[0, 1)` (f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / ((1u64 << 24) as f32))
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; callers in this codebase are not normal-bound).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::MIN_POSITIVE {
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample from a zeta / zipf-like distribution over `[0, n)` with
    /// exponent `s` (used to generate power-law feature popularity as in
    /// text datasets like kdda/news20). Simple inverse-CDF on a cached
    /// table is avoided; instead we use rejection-free inverse transform
    /// on the continuous approximation, which is adequate for data
    /// generation purposes.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.gen_index(n);
        }
        let u = self.next_f64();
        // Inverse CDF of p(x) ∝ x^{-s} on [1, n+1).
        let one_minus_s = 1.0 - s;
        let x = if (one_minus_s).abs() < 1e-12 {
            ((n as f64 + 1.0).ln() * u).exp()
        } else {
            let hi = (n as f64 + 1.0).powf(one_minus_s);
            (1.0 + u * (hi - 1.0)).powf(1.0 / one_minus_s)
        };
        let idx = (x as usize).saturating_sub(1);
        idx.min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut set = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_index(j + 1);
            if set.insert(t) {
                out.push(t);
            } else {
                set.insert(j);
                out.push(j);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 from the public-domain
        // splitmix64.c implementation.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::new(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn split_streams_do_not_collide() {
        let mut root = Xoshiro256::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let eq = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(eq < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Xoshiro256::new(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_range_one() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..100 {
            assert_eq!(r.gen_range(1), 0);
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let x = r.uniform(-2.5, 3.5);
            assert!((-2.5..3.5).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Xoshiro256::new(8);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Xoshiro256::new(11);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..100_000 {
            let v = r.zipf(n, 1.1);
            assert!(v < n);
            counts[v] += 1;
        }
        // Head must dominate tail for a power law.
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[n - 10..].iter().sum();
        assert!(head > 10 * (tail + 1), "head {head} tail {tail}");
    }

    #[test]
    fn zipf_zero_exponent_uniform() {
        let mut r = Xoshiro256::new(12);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.zipf(10, 0.0)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 800.0, "count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Xoshiro256::new(6);
        for _ in 0..100 {
            let n = 1 + r.gen_index(50);
            let k = r.gen_index(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
