//! Crash-durable atomic file publication, shared by the checkpoint
//! writer and the packed-block cache packer.
//!
//! `rename`-over-the-target gives *atomicity* (readers see the old file
//! or the new file, never a torn one) but not *durability*: after a
//! power cut the filesystem may have persisted the rename without the
//! temp file's data blocks, leaving a complete-looking name pointing at
//! garbage. The contract here is the full POSIX sequence:
//!
//! 1. write `<name>.<pid>.tmp` in the target's directory — the pid
//!    suffix keeps two concurrent runs pointed at the same path from
//!    clobbering each other's in-flight temp file (the final `rename`
//!    stays last-writer-wins, which is the intended semantics);
//! 2. `fsync` the temp file, so its data is on disk *before* any name
//!    points at it;
//! 3. `rename` over the target;
//! 4. `fsync` the parent directory, so the rename itself (a directory
//!    mutation) survives a crash. Best-effort on platforms where a
//!    directory cannot be opened or synced (the write is still atomic
//!    and the data blocks are durable either way).

use std::io::Write as _;
use std::path::Path;

/// Write `bytes` to `path` atomically and durably (see module docs).
pub fn write_atomic_durable(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let name = path.file_name().map(|n| n.to_string_lossy()).unwrap_or_default();
    let tmp = path.with_file_name(format!("{name}.{}.tmp", std::process::id()));
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(df) = std::fs::File::open(dir) {
            let _ = df.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publishes_atomically_and_cleans_temp() {
        let dir = std::env::temp_dir().join("dso-fsio-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("target.bin");
        write_atomic_durable(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic_durable(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        // No `*.tmp` (pid-suffixed or otherwise) left behind.
        for entry in std::fs::read_dir(&dir).unwrap() {
            let n = entry.unwrap().file_name().to_string_lossy().to_string();
            assert!(!n.ends_with(".tmp"), "leftover temp file {n}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_rename_removes_temp() {
        // Renaming into a path whose parent does not exist fails; the
        // temp file must not survive the failure.
        let dir = std::env::temp_dir().join("dso-fsio-fail");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("no-such-subdir").join("t.bin");
        // File::create on the temp (same missing dir) already fails.
        assert!(write_atomic_durable(&path, b"x").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
