//! Foundation utilities (offline substitutes for rand / serde_json /
//! criterion / proptest, plus timing/stats/CSV plumbing shared by the
//! coordinator, the experiment drivers and the benches).

pub mod bench;
pub mod csv;
pub mod fsio;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
