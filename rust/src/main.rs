//! `dso` — the leader entrypoint / CLI launcher (L3).

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match dso::cli::main_entry(raw) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
