//! The ring schedule σ_r of Section 3.
//!
//! Paper (1-based): at inner iteration r, processor q owns w-block
//! σ_r(q) = ((q + r − 2) mod p) + 1. We use 0-based indices throughout:
//! σ_r(q) = (q + r) mod p, with r ∈ {0, …, p−1} inside an epoch.
//! After inner iteration r, worker q sends its w-block to the worker
//! that owns it at r+1, which is worker (q − 1 + p) mod p — i.e. blocks
//! travel backwards around the ring, one hop per inner iteration.

#[derive(Clone, Copy, Debug)]
pub struct RingSchedule {
    pub p: usize,
}

impl RingSchedule {
    pub fn new(p: usize) -> RingSchedule {
        assert!(p >= 1);
        RingSchedule { p }
    }

    /// Block of `w` owned by worker q at inner iteration r (0-based).
    #[inline]
    pub fn owned_block(&self, q: usize, r: usize) -> usize {
        (q + r) % self.p
    }

    /// Worker owning block `b` at inner iteration r.
    #[inline]
    pub fn owner_of_block(&self, b: usize, r: usize) -> usize {
        (b + self.p - (r % self.p)) % self.p
    }

    /// Destination worker for q's current block when moving from inner
    /// iteration r to r+1.
    #[inline]
    pub fn send_to(&self, q: usize) -> usize {
        (q + self.p - 1) % self.p
    }

    /// Worker from which q receives its next block.
    #[inline]
    pub fn recv_from(&self, q: usize) -> usize {
        (q + 1) % self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matches_paper_formula_1based() {
        // σ_r(q) = ((q + r − 2) mod p) + 1 in 1-based == (q0 + r0) mod p.
        let p = 5;
        let s = RingSchedule::new(p);
        for q1 in 1..=p {
            for r1 in 1..=p {
                let paper = ((q1 + r1 - 2) % p) + 1;
                assert_eq!(s.owned_block(q1 - 1, r1 - 1) + 1, paper);
            }
        }
    }

    #[test]
    fn each_worker_sees_every_block_once_per_epoch() {
        for p in 1..=8 {
            let s = RingSchedule::new(p);
            for q in 0..p {
                let mut seen: Vec<usize> = (0..p).map(|r| s.owned_block(q, r)).collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..p).collect::<Vec<_>>(), "p={p} q={q}");
            }
        }
    }

    #[test]
    fn active_blocks_disjoint_within_inner_iteration() {
        // At any r, the map q -> owned_block(q, r) must be a bijection —
        // this is what guarantees no two workers share a w block.
        for p in 1..=8 {
            let s = RingSchedule::new(p);
            for r in 0..p {
                let mut blocks: Vec<usize> = (0..p).map(|q| s.owned_block(q, r)).collect();
                blocks.sort_unstable();
                assert_eq!(blocks, (0..p).collect::<Vec<_>>(), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn owner_of_block_inverts_owned_block() {
        prop::check("ring inverse", 200, |g| {
            let p = g.usize_in(1, 12);
            let s = RingSchedule::new(p);
            let q = g.usize_in(0, p - 1);
            let r = g.usize_in(0, 3 * p);
            let b = s.owned_block(q, r);
            prop::assert_that(
                s.owner_of_block(b, r) == q,
                format!("p={p} q={q} r={r} b={b}"),
            )
        });
    }

    #[test]
    fn send_to_delivers_block_to_next_owner() {
        // The worker q sends block b = owned_block(q, r) to send_to(q);
        // that worker must own b at r+1.
        for p in 1..=8 {
            let s = RingSchedule::new(p);
            for r in 0..2 * p {
                for q in 0..p {
                    let b = s.owned_block(q, r);
                    let dst = s.send_to(q);
                    assert_eq!(s.owned_block(dst, r + 1), b, "p={p} q={q} r={r}");
                }
            }
        }
    }

    #[test]
    fn recv_from_is_inverse_of_send_to() {
        for p in 1..=8 {
            let s = RingSchedule::new(p);
            for q in 0..p {
                assert_eq!(s.send_to(s.recv_from(q)), q);
                assert_eq!(s.recv_from(s.send_to(q)), q);
            }
        }
    }

    #[test]
    fn p_equals_one_is_identity() {
        let s = RingSchedule::new(1);
        assert_eq!(s.owned_block(0, 0), 0);
        assert_eq!(s.owned_block(0, 5), 0);
        assert_eq!(s.send_to(0), 0);
    }
}
