//! Partitioning of rows, columns, and the nonzero set Ω (Section 3).
//!
//! DSO partitions {1..m} into I_1..I_p and {1..d} into J_1..J_p, which
//! induces the p×p block grid Ω^(q,r). At inner iteration r, worker q
//! works on Ω^(q, σ_r(q)) with σ_r(q) = ((q+r−2) mod p) + 1 — a
//! diagonal-shift schedule that keeps all active blocks row- and
//! column-disjoint, the property that makes the parallel updates
//! serializable (Lemma 2).

pub mod omega;
pub mod schedule;

pub use omega::{Entry, OmegaBlocks, PackedBlock, PackedBlocks, RowGroup, LANES};
pub use schedule::RingSchedule;

/// A contiguous partition of `[0, n)` into `p` blocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Block boundaries; len = p + 1, bounds[0] = 0, bounds[p] = n.
    pub bounds: Vec<usize>,
}

impl Partition {
    /// Equal-count partition (±1).
    pub fn even(n: usize, p: usize) -> Partition {
        assert!(p >= 1);
        let mut bounds = Vec::with_capacity(p + 1);
        for q in 0..=p {
            bounds.push(q * n / p);
        }
        Partition { bounds }
    }

    /// Weight-balanced contiguous partition: greedy sweep targeting
    /// total_weight/p per block (used to balance nnz across workers so
    /// |Ω^(q,r)| ≈ |Ω|/p², Theorem 1's load assumption).
    pub fn balanced(weights: &[u64], p: usize) -> Partition {
        assert!(p >= 1);
        let n = weights.len();
        let total: u64 = weights.iter().sum();
        let mut bounds = vec![0usize];
        let mut i = 0usize;
        let mut consumed: u64 = 0;
        for q in 0..p - 1 {
            let remaining_blocks = (p - q) as u64;
            let remaining_weight = total - consumed;
            // Adaptive target: remaining weight split over remaining
            // blocks. Recomputing per block absorbs heavy outlier items
            // instead of leaving empty blocks behind them.
            let target = (remaining_weight + remaining_blocks - 1) / remaining_blocks;
            let mut acc: u64 = 0;
            // Leave at least one item per remaining block when possible.
            let reserve = p - q - 1;
            while i < n && n - i > reserve && (acc < target || weights[i] == 0 && acc == 0) {
                acc += weights[i];
                i += 1;
                if acc >= target {
                    break;
                }
            }
            // Degenerate all-zero tail: fall back to even spacing.
            if acc == 0 && i < n && remaining_weight == 0 {
                i = ((q + 1) * n / p).max(i);
            }
            consumed += acc;
            bounds.push(i);
        }
        bounds.push(n);
        Partition { bounds }
    }

    /// Round the interior block boundaries to the nearest multiple of
    /// `lane`, keeping the 0/n endpoints. Every interior bound becomes
    /// a `lane` multiple and every stripe keeps a width of at least
    /// `lane` (the last stripe absorbs the ragged remainder), so a
    /// lane-major packed block over the stripe ends on a chunk
    /// boundary and no worker's w stripe is collapsed to zero by the
    /// rounding. When `n < p·lane` there is no such alignment — the
    /// partition is returned unchanged rather than emptying stripes.
    /// Used for the w (column) stripes of [`Partition::balanced`],
    /// whose data-dependent cuts are otherwise arbitrary; the weight
    /// imbalance the rounding introduces is at most ~`lane` items per
    /// boundary.
    pub fn lane_aligned(mut self, lane: usize) -> Partition {
        assert!(lane >= 1);
        let n = self.n();
        let p = self.p();
        if n < p * lane {
            return self;
        }
        let mut prev = 0usize;
        for q in 1..p {
            // Nearest lane multiple, kept between `prev + lane` (stripe
            // q−1 stays at least one lane wide) and the largest lane
            // multiple that still leaves `lane` items for each of the
            // p−q stripes after this cut. lo ≤ hi holds inductively
            // from n ≥ p·lane (prev is a lane multiple ≤ the previous
            // hi, so prev + lane ≤ (n − (p−q)·lane)/lane·lane by the
            // floor identity), and both ends are lane multiples, so the
            // clamped bound always is too. The explicit min/max order
            // (rather than `clamp`, which panics when lo > hi) plus the
            // final `.min(n)` keeps even an adversarial, non-monotone
            // `bounds` input from ever producing a boundary past n —
            // the out-of-core packer trusts these bounds to index
            // stripe tables (property-tested below with hand-built
            // hostile partitions).
            let lo = prev + lane;
            let hi = (n - (p - q) * lane) / lane * lane;
            let want = (self.bounds[q].min(n) + lane / 2) / lane * lane;
            let r = want.min(hi).max(lo).min(n);
            debug_assert!(lo <= hi && r <= n, "lane_aligned window broken: lo={lo} hi={hi} n={n}");
            self.bounds[q] = r;
            prev = r;
        }
        self
    }

    pub fn p(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn n(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Half-open range of block q.
    #[inline]
    pub fn block(&self, q: usize) -> std::ops::Range<usize> {
        self.bounds[q]..self.bounds[q + 1]
    }

    #[inline]
    pub fn block_len(&self, q: usize) -> usize {
        self.bounds[q + 1] - self.bounds[q]
    }

    /// Owner block of item `i` (binary search).
    #[inline]
    pub fn owner(&self, i: usize) -> usize {
        debug_assert!(i < self.n());
        // partition_point returns count of bounds <= i, in [1, p].
        self.bounds.partition_point(|&b| b <= i) - 1
    }

    /// Verify cover & disjointness.
    pub fn validate(&self) -> Result<(), String> {
        if self.bounds.is_empty() || self.bounds[0] != 0 {
            return Err("bounds must start at 0".into());
        }
        for k in 1..self.bounds.len() {
            if self.bounds[k] < self.bounds[k - 1] {
                return Err(format!("bounds not monotone at {k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn even_partition_covers() {
        let p = Partition::even(10, 3);
        assert_eq!(p.bounds, vec![0, 3, 6, 10]);
        assert_eq!(p.p(), 3);
        assert_eq!(p.block(2), 6..10);
        p.validate().unwrap();
    }

    #[test]
    fn even_partition_more_blocks_than_items() {
        let p = Partition::even(2, 4);
        p.validate().unwrap();
        assert_eq!(p.n(), 2);
        let total: usize = (0..4).map(|q| p.block_len(q)).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn owner_matches_block() {
        let p = Partition::even(100, 7);
        for q in 0..7 {
            for i in p.block(q) {
                assert_eq!(p.owner(i), q, "item {i}");
            }
        }
    }

    #[test]
    fn balanced_partition_balances_weights() {
        // Heavily skewed weights: first item huge.
        let mut weights = vec![1u64; 100];
        weights[0] = 100;
        let p = Partition::balanced(&weights, 4);
        p.validate().unwrap();
        assert_eq!(p.n(), 100);
        // First block should contain just the heavy item.
        assert_eq!(p.block_len(0), 1, "block0 {:?}", p.bounds);
        let sums: Vec<u64> =
            (0..4).map(|q| p.block(q).map(|i| weights[i]).sum()).collect();
        // The three tail blocks split the remaining weight evenly.
        let tail_max = *sums[1..].iter().max().unwrap() as f64;
        let tail_min = *sums[1..].iter().min().unwrap() as f64;
        assert!(tail_max / tail_min.max(1.0) < 1.5, "sums {sums:?}");
        assert!(sums[1..].iter().all(|&s| s > 0), "empty tail block: {sums:?}");
    }

    #[test]
    fn balanced_partition_zero_weights() {
        let p = Partition::balanced(&vec![0u64; 10], 3);
        p.validate().unwrap();
        assert_eq!(p.n(), 10);
        assert_eq!(p.p(), 3);
    }

    #[test]
    fn lane_aligned_rounds_interior_bounds() {
        let w = vec![1u64; 100];
        let p = Partition::balanced(&w, 4).lane_aligned(8);
        p.validate().unwrap();
        assert_eq!(p.n(), 100);
        assert_eq!(p.p(), 4);
        for q in 0..3 {
            assert_eq!(p.block_len(q) % 8, 0, "stripe {q}: {:?}", p.bounds);
        }
        // Last stripe absorbs the ragged remainder.
        let total: usize = (0..4).map(|q| p.block_len(q)).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn lane_aligned_never_collapses_stripes() {
        // Skewed cuts that nearest-rounding alone would collapse:
        // balanced on a hot first item gives bounds like [0,1,...];
        // the aligned partition must keep every stripe ≥ one lane.
        let mut w = vec![1u64; 64];
        w[0] = 1000;
        let p = Partition::balanced(&w, 4).lane_aligned(8);
        p.validate().unwrap();
        for q in 0..4 {
            assert!(p.block_len(q) >= 8, "stripe {q} collapsed: {:?}", p.bounds);
        }
        // Too narrow to align (n < p·lane): returned unchanged.
        let narrow = Partition::balanced(&vec![1u64; 10], 3);
        assert_eq!(narrow.clone().lane_aligned(8).bounds, narrow.bounds);
    }

    #[test]
    fn prop_lane_aligned_keeps_cover_and_widths() {
        prop::check("lane aligned partitions", 100, |g| {
            let n = g.usize_in(1, 400);
            let p_count = g.usize_in(1, 8);
            let lane = *g.pick(&[4usize, 8, 16]);
            let weights: Vec<u64> = (0..n).map(|_| g.usize_in(0, 20) as u64).collect();
            let before = Partition::balanced(&weights, p_count);
            let part = before.clone().lane_aligned(lane);
            part.validate().map_err(|e| e)?;
            prop::assert_that(part.p() == p_count, "block count")?;
            prop::assert_that(part.n() == n, "n preserved")?;
            if n < p_count * lane {
                // Too narrow to align: must be untouched.
                return prop::assert_that(part.bounds == before.bounds, "changed when narrow");
            }
            for q in 1..p_count {
                let b = part.bounds[q];
                prop::assert_that(b % lane == 0, format!("bound {b} not aligned to {lane}"))?;
            }
            for q in 0..p_count {
                prop::assert_that(
                    part.block_len(q) >= lane,
                    format!("stripe {q} narrower than a lane: {:?}", part.bounds),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_lane_aligned_adversarial_bounds_stay_in_range() {
        // The balanced() constructor always emits monotone cuts, but
        // lane_aligned must hold its invariants for *any* bounds a
        // caller could hand-build (hostile skews, repeated cuts, cuts
        // pinned at 0 or n): no boundary past n, every interior bound a
        // lane multiple, every stripe at least one lane wide when the
        // width budget allows. This is the clamp audit's regression
        // net for the out-of-core packer, which indexes stripe tables
        // straight off these bounds.
        prop::check("lane aligned adversarial", 200, |g| {
            let n = g.usize_in(1, 400);
            let p_count = g.usize_in(1, 8);
            let lane = *g.pick(&[4usize, 8, 16]);
            let mut cuts: Vec<usize> = (0..p_count - 1)
                .map(|_| match g.usize_in(0, 9) {
                    0 => 0,        // pinned at the left edge
                    1 => n,        // pinned at the right edge
                    _ => g.usize_in(0, n),
                })
                .collect();
            cuts.sort_unstable();
            let mut bounds = vec![0usize];
            bounds.extend(cuts);
            bounds.push(n);
            let before = Partition { bounds };
            let part = before.clone().lane_aligned(lane);
            part.validate().map_err(|e| e)?;
            prop::assert_that(part.p() == p_count, "block count")?;
            prop::assert_that(part.n() == n, "n preserved")?;
            if n < p_count * lane {
                return prop::assert_that(part.bounds == before.bounds, "changed when narrow");
            }
            for q in 1..p_count {
                let b = part.bounds[q];
                prop::assert_that(b <= n, format!("bound {b} past n={n}"))?;
                prop::assert_that(b % lane == 0, format!("bound {b} not aligned to {lane}"))?;
            }
            for q in 0..p_count {
                prop::assert_that(
                    part.block_len(q) >= lane,
                    format!("stripe {q} narrower than a lane: {:?}", part.bounds),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_partitions_cover_and_disjoint() {
        prop::check("partition cover", 200, |g| {
            let n = g.usize_in(1, 500);
            let p_count = g.usize_in(1, 16);
            let part = if g.bool() {
                Partition::even(n, p_count)
            } else {
                let weights: Vec<u64> =
                    (0..n).map(|_| g.usize_in(0, 20) as u64).collect();
                Partition::balanced(&weights, p_count)
            };
            part.validate().map_err(|e| e)?;
            prop::assert_that(part.p() == p_count, "block count")?;
            prop::assert_that(part.n() == n, "n")?;
            let total: usize = (0..p_count).map(|q| part.block_len(q)).sum();
            prop::assert_that(total == n, format!("cover {total} != {n}"))?;
            // owner() consistent on a sample of items.
            for _ in 0..10.min(n) {
                let i = g.usize_in(0, n - 1);
                let q = part.owner(i);
                prop::assert_that(part.block(q).contains(&i), format!("owner of {i}"))?;
            }
            Ok(())
        });
    }
}
