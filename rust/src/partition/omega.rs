//! The p×p block decomposition of the nonzero set Ω, in packed form.
//!
//! Ω^(q,r) = {(i,j) ∈ Ω : i ∈ I_q, j ∈ J_r}. The seed stored each block
//! as a COO `Vec<Entry>` with 12-byte entries and *global* indices; the
//! hot loop then re-derived everything per nonzero: two offset
//! subtractions, three f64 divisions, and re-loads of row-invariant
//! state (y_i, α_i, 1/(m|Ω_i|)). [`PackedBlocks`] is the §Perf
//! replacement:
//!
//! * **SoA row groups** — each block stores its nonzeros as parallel
//!   arrays `cols` (block-local u32 column ids) and `vals` (f32,
//!   pre-scaled to x/m), segmented into [`RowGroup`]s of consecutive
//!   entries sharing a row. The sweep walks 8 bytes per nonzero instead
//!   of 12 and loads row state once per group instead of once per entry.
//! * **Precomputed reciprocals** — per column-stripe tables
//!   `inv_col[r][lj] = 1/|Ω̄_j|` and per row-stripe tables
//!   `inv_row[q][li] = 1/(m·|Ω_i|)` turn every division in update (8)
//!   into a multiply; folding `x/m` into the stored value removes the
//!   remaining one. The inner loop has **zero divisions and zero offset
//!   subtractions**.
//! * **Block-local indices** — `cols`/`li` are already relative to the
//!   stripe, so the kernel indexes the travelling w block and resident
//!   α block directly.
//!
//! Blocks keep the sampling metadata the update rule needs — the global
//! |Ω_i| (row nnz) and |Ω̄_j| (column nnz) counts of Eq. (8) — computed
//! once on the full matrix and shared. Entries appear in the same
//! (row, col)-sorted order the COO layout used, so the sweep order (and
//! with it the Lemma-2 serializability argument and the parallel ↔
//! replay bit-identity) is unchanged.

use super::Partition;
use crate::data::sparse::Csr;

/// One nonzero entry in global coordinates. Retained as the unit of the
/// scalar *reference* path (`coordinator::updates::sweep_block`), which
/// serves as the correctness oracle for the packed kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub i: u32,
    pub j: u32,
    pub x: f32,
}

/// A run of consecutive entries sharing one (block-local) row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowGroup {
    /// Block-local row id (i − row stripe offset).
    pub li: u32,
    /// Entry range [start, end) into the block's `cols`/`vals`.
    pub start: u32,
    pub end: u32,
}

/// One Ω^(q,r) block in packed SoA form.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PackedBlock {
    /// Non-empty row segments, ascending in `li`; ranges tile
    /// `0..nnz()` exactly.
    pub groups: Vec<RowGroup>,
    /// Block-local column id per entry, sorted within each group.
    pub cols: Vec<u32>,
    /// Pre-scaled value x_ij/m per entry (f32 — matches the parameter
    /// precision; the kernel computes in f64).
    pub vals: Vec<f32>,
    /// Row-stripe height (bound on `li`, exclusive).
    pub n_rows: u32,
    /// Column-stripe width (bound on `cols`, exclusive).
    pub n_cols: u32,
}

impl PackedBlock {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Index of the [`RowGroup`] containing flat entry `k` (binary
    /// search; used by the subsampled sweep path).
    #[inline]
    pub fn group_of(&self, k: u32) -> usize {
        debug_assert!((k as usize) < self.nnz());
        // Groups tile [0, nnz), so the first group with `end > k` owns k.
        self.groups.partition_point(|g| g.end <= k)
    }
}

/// All p×p packed blocks of Ω plus the global per-row/per-column nnz
/// counts and the precomputed reciprocal tables.
#[derive(Clone, Debug)]
pub struct PackedBlocks {
    pub p: usize,
    /// blocks[q * p + r] = packed Ω^(q,r).
    pub blocks: Vec<PackedBlock>,
    /// |Ω_i| for every row i.
    pub row_counts: Vec<u32>,
    /// |Ω̄_j| for every column j.
    pub col_counts: Vec<u32>,
    /// 1/|Ω̄_j| per column stripe r, indexed by block-local column.
    /// 0.0 for empty columns (never read by the sweep: no entries).
    pub inv_col: Vec<Vec<f64>>,
    /// 1/(m·|Ω_i|) per row stripe q, indexed by block-local row.
    /// 0.0 for empty rows (never read by the sweep).
    pub inv_row: Vec<Vec<f64>>,
    /// Number of training points m.
    pub m: usize,
    pub row_part: Partition,
    pub col_part: Partition,
}

/// Backwards-compatible name for the block decomposition.
pub type OmegaBlocks = PackedBlocks;

impl PackedBlocks {
    pub fn build(x: &Csr, row_part: &Partition, col_part: &Partition) -> PackedBlocks {
        assert_eq!(row_part.n(), x.rows);
        assert_eq!(col_part.n(), x.cols);
        assert_eq!(row_part.p(), col_part.p(), "row/col partitions must have equal p");
        let p = row_part.p();
        let m = x.rows;
        let inv_m = 1.0 / (m as f64).max(1.0);

        let mut blocks: Vec<PackedBlock> = (0..p * p)
            .map(|qr| PackedBlock {
                n_rows: row_part.block_len(qr / p) as u32,
                n_cols: col_part.block_len(qr % p) as u32,
                ..PackedBlock::default()
            })
            .collect();

        let row_counts: Vec<u32> = (0..x.rows).map(|i| x.row_nnz(i) as u32).collect();
        let col_counts = x.col_counts();

        for i in 0..x.rows {
            let q = row_part.owner(i);
            let li = (i - row_part.bounds[q]) as u32;
            let (idx, val) = x.row(i);
            for k in 0..idx.len() {
                let j = idx[k] as usize;
                let r = col_part.owner(j);
                let b = &mut blocks[q * p + r];
                let pos = b.cols.len() as u32;
                if matches!(b.groups.last(), Some(g) if g.li == li) {
                    b.groups.last_mut().unwrap().end = pos + 1;
                } else {
                    b.groups.push(RowGroup { li, start: pos, end: pos + 1 });
                }
                b.cols.push(idx[k] - col_part.bounds[r] as u32);
                b.vals.push((val[k] as f64 * inv_m) as f32);
            }
        }

        let inv_col: Vec<Vec<f64>> = (0..p)
            .map(|r| {
                col_part
                    .block(r)
                    .map(|j| {
                        let c = col_counts[j];
                        if c == 0 { 0.0 } else { 1.0 / c as f64 }
                    })
                    .collect()
            })
            .collect();
        let inv_row: Vec<Vec<f64>> = (0..p)
            .map(|q| {
                row_part
                    .block(q)
                    .map(|i| {
                        let c = row_counts[i];
                        if c == 0 { 0.0 } else { 1.0 / (m as f64 * c as f64) }
                    })
                    .collect()
            })
            .collect();

        PackedBlocks {
            p,
            blocks,
            row_counts,
            col_counts,
            inv_col,
            inv_row,
            m,
            row_part: row_part.clone(),
            col_part: col_part.clone(),
        }
    }

    #[inline]
    pub fn block(&self, q: usize, r: usize) -> &PackedBlock {
        &self.blocks[q * self.p + r]
    }

    pub fn total_nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).sum()
    }

    /// Per-row-stripe label tables in f64, ready for the packed kernel
    /// (`y[q][li]` = label of global row `row_part.bounds[q] + li`).
    pub fn stripe_labels(&self, y: &[f32]) -> Vec<Vec<f64>> {
        assert_eq!(y.len(), self.row_part.n());
        (0..self.p)
            .map(|q| self.row_part.block(q).map(|i| y[i] as f64).collect())
            .collect()
    }

    /// Reconstruct a block's entries in global COO coordinates (the
    /// format the scalar reference path consumes). Values are exact:
    /// they are re-read from the source matrix, not un-scaled.
    pub fn block_entries(&self, x: &Csr, q: usize, r: usize) -> Vec<Entry> {
        let mut out = Vec::with_capacity(self.block(q, r).nnz());
        for i in self.row_part.block(q) {
            let (idx, val) = x.row(i);
            for k in 0..idx.len() {
                if self.col_part.owner(idx[k] as usize) == r {
                    out.push(Entry { i: i as u32, j: idx[k], x: val[k] });
                }
            }
        }
        out
    }

    /// Load imbalance across the p "diagonals" used in an epoch: the
    /// epoch's inner iteration r is gated by the slowest worker, i.e.
    /// max_q |Ω^(q, σ_r(q))|. Returns (max diagonal load) / (|Ω|/p) —
    /// 1.0 is perfect balance.
    pub fn epoch_imbalance(&self) -> f64 {
        let ideal = self.total_nnz() as f64 / self.p as f64;
        if ideal == 0.0 {
            return 1.0;
        }
        let mut epoch_cost = 0usize;
        for r in 0..self.p {
            let mut worst = 0usize;
            for q in 0..self.p {
                let b = (q + r) % self.p;
                worst = worst.max(self.block(q, b).nnz());
            }
            epoch_cost += worst;
        }
        epoch_cost as f64 / ideal
    }

    /// Structural invariant check used by tests (and the safety
    /// argument for the kernel's unchecked indexing): blocks cover Ω
    /// exactly, groups tile each block's entry range with ascending
    /// in-bounds local rows, columns are sorted and in-bounds, values
    /// carry x/m, and the reciprocal tables match the counts.
    pub fn validate(&self, x: &Csr) -> Result<(), String> {
        if self.total_nnz() != x.nnz() {
            return Err(format!("cover: {} != {}", self.total_nnz(), x.nnz()));
        }
        if self.m != x.rows {
            return Err(format!("m: {} != {}", self.m, x.rows));
        }
        let inv_m = 1.0 / (self.m as f64).max(1.0);
        for q in 0..self.p {
            for r in 0..self.p {
                let b = self.block(q, r);
                if b.n_rows as usize != self.row_part.block_len(q)
                    || b.n_cols as usize != self.col_part.block_len(r)
                {
                    return Err(format!("block ({q},{r}) stripe dims wrong"));
                }
                let mut next = 0u32;
                let mut prev_li: Option<u32> = None;
                for g in &b.groups {
                    if g.start != next || g.end <= g.start {
                        return Err(format!("block ({q},{r}) groups don't tile entries"));
                    }
                    if let Some(pl) = prev_li {
                        if g.li <= pl {
                            return Err(format!("block ({q},{r}) rows not ascending"));
                        }
                    }
                    if g.li >= b.n_rows {
                        return Err(format!("block ({q},{r}) row {} out of stripe", g.li));
                    }
                    for k in g.start..g.end {
                        let lj = b.cols[k as usize];
                        if lj >= b.n_cols {
                            return Err(format!("block ({q},{r}) col {lj} out of stripe"));
                        }
                        if k > g.start && b.cols[k as usize - 1] >= lj {
                            return Err(format!("block ({q},{r}) cols not sorted"));
                        }
                    }
                    prev_li = Some(g.li);
                    next = g.end;
                }
                if next as usize != b.nnz() {
                    return Err(format!("block ({q},{r}) groups cover {next} != {}", b.nnz()));
                }
                // Cross-check content against the source matrix.
                let expect = self.block_entries(x, q, r);
                if expect.len() != b.nnz() {
                    return Err(format!("block ({q},{r}) entry count vs matrix"));
                }
                let mut k = 0usize;
                for g in &b.groups {
                    for e in &expect[g.start as usize..g.end as usize] {
                        let gi = self.row_part.bounds[q] + g.li as usize;
                        let gj = self.col_part.bounds[r] + b.cols[k] as usize;
                        if gi != e.i as usize || gj != e.j as usize {
                            return Err(format!(
                                "block ({q},{r}) entry {k}: ({gi},{gj}) != ({},{})",
                                e.i, e.j
                            ));
                        }
                        if b.vals[k] != (e.x as f64 * inv_m) as f32 {
                            return Err(format!("block ({q},{r}) entry {k}: value drift"));
                        }
                        k += 1;
                    }
                }
            }
        }
        for r in 0..self.p {
            for (lj, j) in self.col_part.block(r).enumerate() {
                let c = self.col_counts[j];
                let want = if c == 0 { 0.0 } else { 1.0 / c as f64 };
                if self.inv_col[r][lj] != want {
                    return Err(format!("inv_col[{r}][{lj}] wrong"));
                }
            }
        }
        for q in 0..self.p {
            for (li, i) in self.row_part.block(q).enumerate() {
                let c = self.row_counts[i];
                let want = if c == 0 { 0.0 } else { 1.0 / (self.m as f64 * c as f64) };
                if self.inv_row[q][li] != want {
                    return Err(format!("inv_row[{q}][{li}] wrong"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SparseSpec;
    use crate::util::prop;

    fn toy_matrix() -> Csr {
        Csr::from_rows(
            4,
            vec![
                vec![(0, 1.0), (3, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 4.0), (2, 5.0)],
                vec![(3, 6.0)],
                vec![(1, 7.0), (2, 8.0)],
            ],
        )
    }

    #[test]
    fn build_places_entries_correctly() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        om.validate(&x).unwrap();
        // Rows 0..2 are stripe 0; cols 0..1 are stripe 0.
        // Ω^(0,0) = {(0,0,1.0), (1,1,3.0)} → local rows 0 and 1.
        let b00 = om.block(0, 0);
        assert_eq!(b00.nnz(), 2);
        assert_eq!(
            b00.groups,
            vec![
                RowGroup { li: 0, start: 0, end: 1 },
                RowGroup { li: 1, start: 1, end: 2 }
            ]
        );
        assert_eq!(b00.cols, vec![0, 1]);
        // Values are pre-scaled by 1/m (m = 5).
        assert_eq!(b00.vals, vec![(1.0f64 / 5.0) as f32, (3.0f64 / 5.0) as f32]);
        // Ω^(0,1) = {(0,3,2.0)} → local row 0, local col 1.
        let b01 = om.block(0, 1);
        assert_eq!(b01.groups, vec![RowGroup { li: 0, start: 0, end: 1 }]);
        assert_eq!(b01.cols, vec![1]);
        assert_eq!(b01.vals, vec![(2.0f64 / 5.0) as f32]);
    }

    #[test]
    fn counts_and_reciprocals_match_matrix() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        assert_eq!(om.row_counts, vec![2, 1, 2, 1, 2]);
        assert_eq!(om.col_counts, vec![2, 2, 2, 2]);
        assert_eq!(om.total_nnz(), x.nnz());
        // inv_col[r][lj] = 1/|Ω̄_j|, inv_row[q][li] = 1/(m|Ω_i|).
        assert_eq!(om.inv_col[0], vec![0.5, 0.5]);
        assert_eq!(om.inv_col[1], vec![0.5, 0.5]);
        assert_eq!(om.inv_row[0], vec![1.0 / 10.0, 1.0 / 5.0]);
        assert_eq!(om.inv_row[1], vec![1.0 / 10.0, 1.0 / 5.0, 1.0 / 10.0]);
    }

    #[test]
    fn groups_ascending_and_cols_sorted() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        for q in 0..2 {
            for r in 0..2 {
                let b = om.block(q, r);
                for gk in 1..b.groups.len() {
                    assert!(b.groups[gk - 1].li < b.groups[gk].li, "block ({q},{r})");
                }
                for g in &b.groups {
                    for k in (g.start + 1)..g.end {
                        assert!(
                            b.cols[k as usize - 1] < b.cols[k as usize],
                            "block ({q},{r}) cols"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn group_of_finds_owning_row() {
        let x = toy_matrix();
        let rp = Partition::even(5, 1);
        let cp = Partition::even(4, 1);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let b = om.block(0, 0);
        for (gi, g) in b.groups.iter().enumerate() {
            for k in g.start..g.end {
                assert_eq!(b.group_of(k), gi, "entry {k}");
            }
        }
    }

    #[test]
    fn block_entries_reconstruct_exact_values() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let e00 = om.block_entries(&x, 0, 0);
        assert_eq!(
            e00,
            vec![Entry { i: 0, j: 0, x: 1.0 }, Entry { i: 1, j: 1, x: 3.0 }]
        );
        assert_eq!(om.block_entries(&x, 0, 1), vec![Entry { i: 0, j: 3, x: 2.0 }]);
        let total: usize =
            (0..2).flat_map(|q| (0..2).map(move |r| (q, r)))
                .map(|(q, r)| om.block_entries(&x, q, r).len())
                .sum();
        assert_eq!(total, x.nnz());
    }

    #[test]
    fn stripe_labels_follow_row_partition() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let y = [1.0f32, -1.0, 1.0, -1.0, 1.0];
        let yl = om.stripe_labels(&y);
        assert_eq!(yl[0], vec![1.0, -1.0]);
        assert_eq!(yl[1], vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn prop_blocks_cover_and_are_disjoint() {
        prop::check("omega blocks", 50, |g| {
            let m = g.usize_in(2, 80);
            let d = g.usize_in(2, 60);
            let p = g.usize_in(1, 6.min(m).min(d));
            let ds = SparseSpec {
                name: "prop".into(),
                m,
                d,
                nnz_per_row: g.f64_in(1.0, 6.0),
                zipf_s: g.f64_in(0.0, 1.2),
                label_noise: 0.0,
                pos_frac: 0.5,
                seed: g.case_seed,
            }
            .generate();
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            om.validate(&ds.x).map_err(|e| e)?;
            prop::assert_that(om.epoch_imbalance() >= 0.99, "imbalance >= 1")
        });
    }

    #[test]
    fn imbalance_perfect_on_uniform_diagonal() {
        // Diagonal matrix, p = n: all entries are on the r=0 diagonal:
        // epoch cost = 1 (r=0) + 0 + 0, ideal = 1 -> imbalance 1.0.
        let x = Csr::from_rows(3, vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(2, 1.0)]]);
        let rp = Partition::even(3, 3);
        let cp = Partition::even(3, 3);
        let om = PackedBlocks::build(&x, &rp, &cp);
        assert!((om.epoch_imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "equal p")]
    fn mismatched_p_panics() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 3);
        PackedBlocks::build(&x, &rp, &cp);
    }
}
