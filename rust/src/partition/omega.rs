//! The p×p block decomposition of the nonzero set Ω.
//!
//! Ω^(q,r) = {(i,j) ∈ Ω : i ∈ I_q, j ∈ J_r}. Each block is stored as a
//! COO list sorted by (row, col) — the order the worker sweeps. Blocks
//! also carry the sampling metadata the update rule needs: the global
//! |Ω_i| (row nnz) and |Ω̄_j| (column nnz) counts appear in Eq. (8)'s
//! scaling, so they are computed once on the full matrix and shared.

use super::Partition;
use crate::data::sparse::Csr;

/// One nonzero entry within a block (global coordinates).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub i: u32,
    pub j: u32,
    pub x: f32,
}

/// All p×p blocks of Ω plus the global per-row/per-column nnz counts.
#[derive(Clone, Debug)]
pub struct OmegaBlocks {
    pub p: usize,
    /// blocks[q * p + r] = entries of Ω^(q,r).
    pub blocks: Vec<Vec<Entry>>,
    /// |Ω_i| for every row i.
    pub row_counts: Vec<u32>,
    /// |Ω̄_j| for every column j.
    pub col_counts: Vec<u32>,
    pub row_part: Partition,
    pub col_part: Partition,
}

impl OmegaBlocks {
    pub fn build(x: &Csr, row_part: &Partition, col_part: &Partition) -> OmegaBlocks {
        assert_eq!(row_part.n(), x.rows);
        assert_eq!(col_part.n(), x.cols);
        assert_eq!(row_part.p(), col_part.p(), "row/col partitions must have equal p");
        let p = row_part.p();
        let mut blocks: Vec<Vec<Entry>> = vec![Vec::new(); p * p];
        let row_counts: Vec<u32> =
            (0..x.rows).map(|i| x.row_nnz(i) as u32).collect();
        let col_counts = x.col_counts();
        for i in 0..x.rows {
            let q = row_part.owner(i);
            let (idx, val) = x.row(i);
            for k in 0..idx.len() {
                let j = idx[k] as usize;
                let r = col_part.owner(j);
                blocks[q * p + r].push(Entry { i: i as u32, j: idx[k], x: val[k] });
            }
        }
        OmegaBlocks {
            p,
            blocks,
            row_counts,
            col_counts,
            row_part: row_part.clone(),
            col_part: col_part.clone(),
        }
    }

    #[inline]
    pub fn block(&self, q: usize, r: usize) -> &[Entry] {
        &self.blocks[q * self.p + r]
    }

    pub fn total_nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.len()).sum()
    }

    /// Load imbalance across the p "diagonals" used in an epoch: the
    /// epoch's inner iteration r is gated by the slowest worker, i.e.
    /// max_q |Ω^(q, σ_r(q))|. Returns (max diagonal load) / (|Ω|/p) —
    /// 1.0 is perfect balance.
    pub fn epoch_imbalance(&self) -> f64 {
        let ideal = self.total_nnz() as f64 / self.p as f64;
        if ideal == 0.0 {
            return 1.0;
        }
        let mut epoch_cost = 0usize;
        for r in 0..self.p {
            let mut worst = 0usize;
            for q in 0..self.p {
                let b = (q + r) % self.p;
                worst = worst.max(self.block(q, b).len());
            }
            epoch_cost += worst;
        }
        epoch_cost as f64 / ideal
    }

    /// Structural invariant check used by tests: every entry lands in
    /// the block of its owners, blocks cover Ω exactly.
    pub fn validate(&self, x: &Csr) -> Result<(), String> {
        if self.total_nnz() != x.nnz() {
            return Err(format!("cover: {} != {}", self.total_nnz(), x.nnz()));
        }
        for q in 0..self.p {
            for r in 0..self.p {
                for e in self.block(q, r) {
                    if self.row_part.owner(e.i as usize) != q {
                        return Err(format!("entry ({},{}) wrong row block", e.i, e.j));
                    }
                    if self.col_part.owner(e.j as usize) != r {
                        return Err(format!("entry ({},{}) wrong col block", e.i, e.j));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SparseSpec;
    use crate::util::prop;

    fn toy_matrix() -> Csr {
        Csr::from_rows(
            4,
            vec![
                vec![(0, 1.0), (3, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 4.0), (2, 5.0)],
                vec![(3, 6.0)],
                vec![(1, 7.0), (2, 8.0)],
            ],
        )
    }

    #[test]
    fn build_places_entries_correctly() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = OmegaBlocks::build(&x, &rp, &cp);
        om.validate(&x).unwrap();
        // Rows 0..2 are block 0; cols 0..1 are block 0.
        // Ω^(0,0) = {(0,0,1.0), (1,1,3.0)}.
        let b00 = om.block(0, 0);
        assert_eq!(b00.len(), 2);
        assert_eq!(b00[0], Entry { i: 0, j: 0, x: 1.0 });
        assert_eq!(b00[1], Entry { i: 1, j: 1, x: 3.0 });
        // Ω^(0,1) = {(0,3,2.0)}.
        assert_eq!(om.block(0, 1), &[Entry { i: 0, j: 3, x: 2.0 }]);
    }

    #[test]
    fn counts_match_matrix() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = OmegaBlocks::build(&x, &rp, &cp);
        assert_eq!(om.row_counts, vec![2, 1, 2, 1, 2]);
        assert_eq!(om.col_counts, vec![2, 2, 2, 2]);
        assert_eq!(om.total_nnz(), x.nnz());
    }

    #[test]
    fn entries_sorted_within_block_by_row() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = OmegaBlocks::build(&x, &rp, &cp);
        for q in 0..2 {
            for r in 0..2 {
                let b = om.block(q, r);
                for k in 1..b.len() {
                    assert!(
                        (b[k - 1].i, b[k - 1].j) < (b[k].i, b[k].j),
                        "block ({q},{r}) not sorted"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_blocks_cover_and_are_disjoint() {
        prop::check("omega blocks", 50, |g| {
            let m = g.usize_in(2, 80);
            let d = g.usize_in(2, 60);
            let p = g.usize_in(1, 6.min(m).min(d));
            let ds = SparseSpec {
                name: "prop".into(),
                m,
                d,
                nnz_per_row: g.f64_in(1.0, 6.0),
                zipf_s: g.f64_in(0.0, 1.2),
                label_noise: 0.0,
                pos_frac: 0.5,
                seed: g.case_seed,
            }
            .generate();
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            let om = OmegaBlocks::build(&ds.x, &rp, &cp);
            om.validate(&ds.x).map_err(|e| e)?;
            prop::assert_that(om.epoch_imbalance() >= 0.99, "imbalance >= 1")
        });
    }

    #[test]
    fn imbalance_perfect_on_uniform_diagonal() {
        // Diagonal matrix, p = n: every block has exactly one entry on
        // the diagonal blocks and zero elsewhere — per inner iteration
        // exactly one active diagonal has entries... with even
        // partition each diagonal r has max block size 1 -> epoch cost p,
        // ideal = nnz/p = 1 -> imbalance = p. Just verify it computes.
        let x = Csr::from_rows(3, vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(2, 1.0)]]);
        let rp = Partition::even(3, 3);
        let cp = Partition::even(3, 3);
        let om = OmegaBlocks::build(&x, &rp, &cp);
        // All entries are on the r=0 diagonal: epoch cost = 1 (r=0) + 0 + 0,
        // ideal = 1 -> imbalance 1.0.
        assert!((om.epoch_imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "equal p")]
    fn mismatched_p_panics() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 3);
        OmegaBlocks::build(&x, &rp, &cp);
    }
}
