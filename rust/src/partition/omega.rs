//! The p×p block decomposition of the nonzero set Ω, in packed
//! **lane-major** form.
//!
//! Ω^(q,r) = {(i,j) ∈ Ω : i ∈ I_q, j ∈ J_r}. The seed stored each block
//! as a COO `Vec<Entry>` with 12-byte entries and *global* indices; the
//! hot loop then re-derived everything per nonzero: two offset
//! subtractions, three f64 divisions, and re-loads of row-invariant
//! state (y_i, α_i, 1/(m|Ω_i|)). [`PackedBlocks`] is the §Perf
//! replacement:
//!
//! * **SoA row groups** — each block stores its nonzeros as parallel
//!   arrays `cols` (block-local u32 column ids) and `vals` (f32,
//!   pre-scaled to x/m), segmented into [`RowGroup`]s of consecutive
//!   entries sharing a row. The sweep walks 8 bytes per nonzero instead
//!   of 12 and loads row state once per group instead of once per entry.
//! * **Lane-major padding** — a row group of `len ≥ LANES` entries is
//!   stored as whole chunks of [`LANES`] (= 8) columns/values: its
//!   ragged tail is padded with *sentinel* entries (`col = 0`,
//!   `val = 0.0`) up to the next lane multiple, so the SIMD sweep
//!   (`coordinator::updates::sweep_lanes`) runs branch-free full-width
//!   arithmetic over every chunk. Within one row all columns are
//!   distinct, so the 8 w-updates of a chunk are write-conflict-free —
//!   the property the lane kernel exploits. Groups shorter than `LANES`
//!   are stored tight (no padding) and swept scalar; padding them would
//!   cost up to 8× storage on very sparse blocks for no speedup.
//! * **Logical vs physical coordinates** — [`RowGroup::start`]/`end`
//!   keep the *logical* (sentinel-free) entry numbering the sampling
//!   path and the serializability argument are stated in; `pad_start`
//!   locates the group's physical lane region in `cols`/`vals`.
//!   Sampling over `[0, nnz())` therefore draws exactly the same
//!   entries (and RNG stream) as the pre-lane layout.
//! * **Precomputed reciprocals** — per column-stripe tables
//!   `inv_col[r][lj] = 1/|Ω̄_j|` (and their f32 mirror `inv_col32`,
//!   consumed by the f32 lane kernel) and per row-stripe tables
//!   `inv_row[q][li] = 1/(m·|Ω_i|)` turn every division in update (8)
//!   into a multiply; folding `x/m` into the stored value removes the
//!   remaining one. The inner loop has **zero divisions and zero offset
//!   subtractions**.
//! * **Block-local indices** — `cols`/`li` are already relative to the
//!   stripe, so the kernel indexes the travelling w block and resident
//!   α block directly.
//! * **Cold side table** — `entry_group` maps each logical entry to its
//!   owning row group so the subsampled sweep does one array load per
//!   sampled entry instead of a binary search over groups. It costs
//!   4 bytes per nonzero (+50% on the 8-byte packed entries), so it is
//!   only materialized via [`PackedBlocks::with_sampling_tables`] when
//!   the `updates_per_block` configuration actually samples; full
//!   sweeps leave it empty and the sampled path falls back to the
//!   binary search.
//!
//! ## Sentinel-padding invariants
//!
//! Established by [`PackedBlock::finalize_lanes`] and re-checked by
//! [`PackedBlocks::validate`] (tests) and `check_packed_bounds`
//! (every sweep):
//!
//! 1. Physical group regions tile `[0, padded_nnz())`: group g occupies
//!    `pad_start .. pad_start + lane_span(len)`, and the next group's
//!    `pad_start` is exactly that end.
//! 2. A region is padded iff `len ≥ LANES`, to the next multiple of
//!    `LANES`; the first `len` slots are the real entries in their
//!    original (row, col)-sorted order.
//! 3. Sentinel slots carry `col = SENTINEL_COL` (a valid block-local
//!    column, so speculative full-width gathers stay in bounds) and
//!    `val = 0.0`. The lane kernel **never stores** lane results past a
//!    chunk's real length, so sentinel columns are read-only: padding
//!    cannot perturb any w, α, or accumulator state (property-tested in
//!    `tests/lane_kernel.rs` by mutating sentinels and requiring
//!    bit-identical output).
//!
//! Blocks keep the sampling metadata the update rule needs — the global
//! |Ω_i| (row nnz) and |Ω̄_j| (column nnz) counts of Eq. (8) — computed
//! once on the full matrix and shared. Logical entries appear in the
//! same (row, col)-sorted order the COO layout used, so the sweep order
//! (and with it the Lemma-2 serializability argument and the parallel ↔
//! replay bit-identity) is unchanged.
//!
//! ## Float-summation-order caveat
//!
//! The scalar packed kernel (`sweep_packed`) is numerically *identical*
//! to the PR-1 kernel on this layout (same entries, same order, same
//! f64 arithmetic). The lane kernel (`sweep_lanes`) evaluates the
//! w-side gradient/step/clamp in 8-wide **f32** arithmetic and is
//! therefore *tolerance-equivalent* (≤1e-5 relative after a sweep), not
//! bit-identical, to the scalar path; bit-identity tests (threaded ≡
//! replay) hold on either path because both engine executions dispatch
//! to the same kernel, but cross-kernel comparisons must use
//! tolerances. See `coordinator::updates` for the exact divergence
//! points.

use super::Partition;
use crate::data::cache::BlockStore;
use crate::data::sparse::Csr;
use crate::simd::aligned::{is_aligned, AVec};

/// SIMD lane width of the value lanes: 8 × f32 = one 256-bit vector.
/// The layout pads lane-eligible row groups to a multiple of this.
pub const LANES: usize = 8;

/// Block-local column id stored in sentinel (padding) slots. Any valid
/// column works — sentinels are only ever *read* (speculatively, by the
/// full-width lane gathers), never written through.
pub const SENTINEL_COL: u32 = 0;

/// Physical storage span of a row group with `len` real entries: padded
/// to the next `LANES` multiple when lane-eligible, tight otherwise.
#[inline]
pub fn lane_span(len: usize) -> usize {
    if len >= LANES {
        len.div_ceil(LANES) * LANES
    } else {
        len
    }
}

/// One nonzero entry in global coordinates. Retained as the unit of the
/// scalar *reference* path (`coordinator::updates::sweep_block`), which
/// serves as the correctness oracle for the packed kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub i: u32,
    pub j: u32,
    pub x: f32,
}

/// A run of consecutive entries sharing one (block-local) row.
///
/// `start`/`end` are **logical** entry coordinates (no sentinels):
/// group g's real entries are logical `[start, end)`. `pad_start` is
/// the **physical** index of the group's first entry in `cols`/`vals`;
/// the group physically occupies `pad_start .. pad_start +
/// lane_span(len())`, with sentinel padding after the first `len()`
/// slots when lane-eligible.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowGroup {
    /// Block-local row id (i − row stripe offset).
    pub li: u32,
    /// Logical entry range [start, end): real entries only.
    pub start: u32,
    pub end: u32,
    /// Physical start of this group's (possibly padded) lane region.
    pub pad_start: u32,
}

impl RowGroup {
    /// Number of real entries in the group.
    #[inline]
    pub fn len(&self) -> usize {
        (self.end - self.start) as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.end == self.start
    }

    /// Physical storage span (real entries + sentinel padding).
    #[inline]
    pub fn padded_len(&self) -> usize {
        lane_span(self.len())
    }

    /// Whether the lane kernel processes this group in LANES-wide
    /// chunks (otherwise it falls back to the scalar loop).
    #[inline]
    pub fn lane_eligible(&self) -> bool {
        self.len() >= LANES
    }
}

/// One Ω^(q,r) block in packed, lane-major SoA form.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PackedBlock {
    /// Non-empty row segments, ascending in `li`; logical ranges tile
    /// `0..nnz()` and physical regions tile `0..padded_nnz()` exactly.
    pub groups: Vec<RowGroup>,
    /// Block-local column id per physical slot, sorted within each
    /// group's real prefix; sentinel slots hold [`SENTINEL_COL`].
    /// 64-byte-aligned storage ([`BlockStore`]: an owned [`AVec`] after
    /// `build`, or an mmap view after `data::cache::open` — both honor
    /// the §Alignment contract the explicit-SIMD backend's vector loads
    /// rely on).
    pub cols: BlockStore<u32>,
    /// Pre-scaled value x_ij/m per physical slot (f32 — matches the
    /// parameter precision; the scalar kernel computes in f64).
    /// Sentinel slots hold 0.0. 64-byte-aligned like `cols`.
    pub vals: BlockStore<f32>,
    /// Row-stripe height (bound on `li`, exclusive).
    pub n_rows: u32,
    /// Column-stripe width (bound on `cols`, exclusive).
    pub n_cols: u32,
    /// Cold side table for the subsampled sweep: owning group index per
    /// *logical* entry (replaces the old per-sample binary search).
    /// Empty unless built via [`PackedBlocks::with_sampling_tables`] —
    /// it is pure overhead for full sweeps.
    pub entry_group: Vec<u32>,
    /// Number of lane-eligible groups (len ≥ LANES). The engines
    /// dispatch to `sweep_lanes` iff this is nonzero.
    pub lane_groups: u32,
}

impl PackedBlock {
    /// Number of *real* entries (sentinel padding excluded).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.groups.last().map_or(0, |g| g.end as usize)
    }

    /// Physical storage slots, including sentinel padding.
    #[inline]
    pub fn padded_nnz(&self) -> usize {
        self.vals.len()
    }

    /// Whether any row group is lane-eligible — the engines' dispatch
    /// predicate between `sweep_lanes` and scalar `sweep_packed`.
    #[inline]
    pub fn has_lanes(&self) -> bool {
        self.lane_groups > 0
    }

    /// Index of the [`RowGroup`] containing *logical* entry `k` (binary
    /// search; the hot sampled path uses the `entry_group` side table —
    /// this stays as the table's independent cross-check).
    #[inline]
    pub fn group_of(&self, k: u32) -> usize {
        debug_assert!((k as usize) < self.nnz());
        // Logical group ranges tile [0, nnz), so the first group with
        // `end > k` owns k.
        self.groups.partition_point(|g| g.end <= k)
    }

    /// Index of the [`RowGroup`] containing *logical* entry `k`: the
    /// cold side table when it has been built, the binary search
    /// otherwise.
    #[inline]
    pub fn group_of_cached(&self, k: u32) -> usize {
        if self.entry_group.is_empty() {
            self.group_of(k)
        } else {
            self.entry_group[k as usize] as usize
        }
    }

    /// Physical slot of *logical* entry `k`.
    #[inline]
    pub fn physical_of(&self, k: u32) -> usize {
        let g = &self.groups[self.group_of_cached(k)];
        (g.pad_start + (k - g.start)) as usize
    }

    /// Materialize the `entry_group` side table (idempotent).
    pub fn build_entry_group(&mut self) {
        if self.entry_group.len() == self.nnz() {
            return;
        }
        self.entry_group = Vec::with_capacity(self.nnz());
        for (gi, g) in self.groups.iter().enumerate() {
            for _ in g.start..g.end {
                self.entry_group.push(gi as u32);
            }
        }
    }

    /// Convert a tightly-built block (groups with logical ranges only,
    /// `cols`/`vals` holding exactly the real entries in order) into
    /// the lane-major layout: assign physical `pad_start` offsets,
    /// insert sentinel slots after ragged tails of lane-eligible
    /// groups, and count lane-eligible groups. Idempotent on a block
    /// that carries no padding.
    pub fn finalize_lanes(&mut self) {
        let nnz = self.groups.last().map_or(0, |g| g.end) as usize;
        debug_assert_eq!(nnz, self.cols.len(), "finalize_lanes expects tight storage");
        self.lane_groups = self.groups.iter().filter(|g| g.lane_eligible()).count() as u32;
        let padded: usize = self.groups.iter().map(|g| lane_span(g.len())).sum();
        if padded == nnz {
            // No sentinels anywhere: physical layout == logical layout.
            for g in self.groups.iter_mut() {
                g.pad_start = g.start;
            }
            return;
        }
        let mut cols = AVec::with_capacity(padded);
        let mut vals = AVec::with_capacity(padded);
        for g in self.groups.iter_mut() {
            g.pad_start = cols.len() as u32;
            cols.extend_from_slice(&self.cols[g.start as usize..g.end as usize]);
            vals.extend_from_slice(&self.vals[g.start as usize..g.end as usize]);
            for _ in g.len()..g.padded_len() {
                cols.push(SENTINEL_COL);
                vals.push(0.0);
            }
        }
        self.cols = cols.into();
        self.vals = vals.into();
    }
}

/// All p×p packed blocks of Ω plus the global per-row/per-column nnz
/// counts and the precomputed reciprocal tables.
#[derive(Clone, Debug)]
pub struct PackedBlocks {
    pub p: usize,
    /// blocks[q * p + r] = packed Ω^(q,r).
    pub blocks: Vec<PackedBlock>,
    /// |Ω_i| for every row i.
    pub row_counts: Vec<u32>,
    /// |Ω̄_j| for every column j.
    pub col_counts: Vec<u32>,
    /// 1/|Ω̄_j| per column stripe r, indexed by block-local column.
    /// 0.0 for empty columns (never read by the sweep: no entries).
    pub inv_col: Vec<Vec<f64>>,
    /// f32 mirror of `inv_col`, gathered by the 8-wide f32 lane kernel
    /// (half the bandwidth of the f64 table on the gather port).
    /// 64-byte-aligned per stripe — the AVX2 backend's
    /// `_mm256_i32gather_ps` base. [`BlockStore`] so an out-of-core run
    /// maps the table instead of owning it.
    pub inv_col32: Vec<BlockStore<f32>>,
    /// 1/(m·|Ω_i|) per row stripe q, indexed by block-local row.
    /// 0.0 for empty rows (never read by the sweep).
    pub inv_row: Vec<Vec<f64>>,
    /// Number of training points m.
    pub m: usize,
    pub row_part: Partition,
    pub col_part: Partition,
}

/// Backwards-compatible name for the block decomposition.
pub type OmegaBlocks = PackedBlocks;

impl PackedBlocks {
    pub fn build(x: &Csr, row_part: &Partition, col_part: &Partition) -> PackedBlocks {
        assert_eq!(row_part.n(), x.rows);
        assert_eq!(col_part.n(), x.cols);
        assert_eq!(row_part.p(), col_part.p(), "row/col partitions must have equal p");
        let p = row_part.p();
        let m = x.rows;
        let inv_m = 1.0 / (m as f64).max(1.0);

        let mut blocks: Vec<PackedBlock> = (0..p * p)
            .map(|qr| PackedBlock {
                n_rows: row_part.block_len(qr / p) as u32,
                n_cols: col_part.block_len(qr % p) as u32,
                ..PackedBlock::default()
            })
            .collect();

        let row_counts: Vec<u32> = (0..x.rows).map(|i| x.row_nnz(i) as u32).collect();
        let col_counts = x.col_counts();

        for i in 0..x.rows {
            let q = row_part.owner(i);
            let li = (i - row_part.bounds[q]) as u32;
            let (idx, val) = x.row(i);
            for k in 0..idx.len() {
                let j = idx[k] as usize;
                let r = col_part.owner(j);
                let b = &mut blocks[q * p + r];
                let pos = b.cols.len() as u32;
                if matches!(b.groups.last(), Some(g) if g.li == li) {
                    b.groups.last_mut().unwrap().end = pos + 1;
                } else {
                    b.groups.push(RowGroup { li, start: pos, end: pos + 1, pad_start: 0 });
                }
                b.cols.push(idx[k] - col_part.bounds[r] as u32);
                b.vals.push((val[k] as f64 * inv_m) as f32);
            }
        }
        for b in blocks.iter_mut() {
            b.finalize_lanes();
        }

        let inv_col: Vec<Vec<f64>> = (0..p)
            .map(|r| {
                col_part
                    .block(r)
                    .map(|j| {
                        let c = col_counts[j];
                        if c == 0 { 0.0 } else { 1.0 / c as f64 }
                    })
                    .collect()
            })
            .collect();
        let inv_col32: Vec<BlockStore<f32>> =
            inv_col.iter().map(|t| t.iter().map(|&v| v as f32).collect()).collect();
        let inv_row: Vec<Vec<f64>> = (0..p)
            .map(|q| {
                row_part
                    .block(q)
                    .map(|i| {
                        let c = row_counts[i];
                        if c == 0 { 0.0 } else { 1.0 / (m as f64 * c as f64) }
                    })
                    .collect()
            })
            .collect();

        PackedBlocks {
            p,
            blocks,
            row_counts,
            col_counts,
            inv_col,
            inv_col32,
            inv_row,
            m,
            row_part: row_part.clone(),
            col_part: col_part.clone(),
        }
    }

    /// Materialize the per-entry `entry_group` side tables on every
    /// block, turning the subsampled sweep's group lookup into one cold
    /// load. Costs 4 bytes per nonzero — call it only when
    /// `updates_per_block` sampling is actually configured (the engines
    /// do); full sweeps never read the tables.
    pub fn with_sampling_tables(mut self) -> PackedBlocks {
        for b in self.blocks.iter_mut() {
            b.build_entry_group();
        }
        self
    }

    #[inline]
    pub fn block(&self, q: usize, r: usize) -> &PackedBlock {
        &self.blocks[q * self.p + r]
    }

    pub fn total_nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).sum()
    }

    /// Per-row-stripe label tables in f64, ready for the packed kernel
    /// (`y[q][li]` = label of global row `row_part.bounds[q] + li`).
    pub fn stripe_labels(&self, y: &[f32]) -> Vec<Vec<f64>> {
        assert_eq!(y.len(), self.row_part.n());
        (0..self.p)
            .map(|q| self.row_part.block(q).map(|i| y[i] as f64).collect())
            .collect()
    }

    /// Per row-stripe **affine-α bias coefficients** for the square
    /// loss, in f32: `bias_hr[q][li] = (y_i · 1/(m·|Ω_i|)) as f32`.
    ///
    /// The square loss has h'(α, y) = y − α (affine in α with identity
    /// projection — `losses::kernel::AffineLossK`), so the α side of
    /// update (8) at entry (i, j) is α ← a·α + b with the α-independent
    /// gradient part `b/η = y_i·hr − w_j·x_ij`. Its first term —
    /// `dual_bias(y_i)·hr`, chunk-invariant *and* sweep-invariant — is
    /// hoisted here, computed once per run next to the reciprocal
    /// tables instead of once per lane chunk inside
    /// `coordinator::updates::sweep_lanes_affine`. Like
    /// [`PackedBlocks::stripe_labels`] it needs the label vector, so it
    /// is a method rather than a `build` field; 0.0 for empty rows
    /// (never read by any sweep). Cost is 4 bytes/row — the engines
    /// build it unconditionally (it is dead weight only when a
    /// non-square loss runs).
    pub fn stripe_alpha_bias(&self, y: &[f32]) -> Vec<AVec<f32>> {
        assert_eq!(y.len(), self.row_part.n());
        (0..self.p)
            .map(|q| {
                self.row_part
                    .block(q)
                    .enumerate()
                    .map(|(li, i)| (y[i] as f64 * self.inv_row[q][li]) as f32)
                    .collect()
            })
            .collect()
    }

    /// Reconstruct a block's entries in global COO coordinates (the
    /// format the scalar reference path consumes). Values are exact:
    /// they are re-read from the source matrix, not un-scaled.
    pub fn block_entries(&self, x: &Csr, q: usize, r: usize) -> Vec<Entry> {
        let mut out = Vec::with_capacity(self.block(q, r).nnz());
        for i in self.row_part.block(q) {
            let (idx, val) = x.row(i);
            for k in 0..idx.len() {
                if self.col_part.owner(idx[k] as usize) == r {
                    out.push(Entry { i: i as u32, j: idx[k], x: val[k] });
                }
            }
        }
        out
    }

    /// Load imbalance across the p "diagonals" used in an epoch: the
    /// epoch's inner iteration r is gated by the slowest worker, i.e.
    /// max_q |Ω^(q, σ_r(q))|. Returns (max diagonal load) / (|Ω|/p) —
    /// 1.0 is perfect balance.
    pub fn epoch_imbalance(&self) -> f64 {
        let ideal = self.total_nnz() as f64 / self.p as f64;
        if ideal == 0.0 {
            return 1.0;
        }
        let mut epoch_cost = 0usize;
        for r in 0..self.p {
            let mut worst = 0usize;
            for q in 0..self.p {
                let b = (q + r) % self.p;
                worst = worst.max(self.block(q, b).nnz());
            }
            epoch_cost += worst;
        }
        epoch_cost as f64 / ideal
    }

    /// Structural invariant check used by tests (and the safety
    /// argument for the kernels' unchecked indexing): blocks cover Ω
    /// exactly, logical group ranges tile each block's entry numbering
    /// with ascending in-bounds local rows, physical regions tile the
    /// padded storage with sentinels only where the invariants allow
    /// them, columns are sorted and in-bounds, values carry x/m, the
    /// side tables are consistent, and the reciprocal tables match the
    /// counts.
    pub fn validate(&self, x: &Csr) -> Result<(), String> {
        if self.total_nnz() != x.nnz() {
            return Err(format!("cover: {} != {}", self.total_nnz(), x.nnz()));
        }
        if self.m != x.rows {
            return Err(format!("m: {} != {}", self.m, x.rows));
        }
        let inv_m = 1.0 / (self.m as f64).max(1.0);
        for q in 0..self.p {
            for r in 0..self.p {
                let b = self.block(q, r);
                if b.n_rows as usize != self.row_part.block_len(q)
                    || b.n_cols as usize != self.col_part.block_len(r)
                {
                    return Err(format!("block ({q},{r}) stripe dims wrong"));
                }
                if b.vals.len() != b.cols.len() {
                    return Err(format!("block ({q},{r}) cols/vals length mismatch"));
                }
                if !is_aligned(&b.cols[..]) || !is_aligned(&b.vals[..]) {
                    return Err(format!("block ({q},{r}) lane storage not 64B-aligned"));
                }
                let mut next = 0u32;
                let mut pnext = 0usize;
                let mut prev_li: Option<u32> = None;
                for g in &b.groups {
                    if g.start != next || g.end <= g.start {
                        return Err(format!("block ({q},{r}) groups don't tile entries"));
                    }
                    if g.pad_start as usize != pnext {
                        return Err(format!("block ({q},{r}) padded regions don't tile"));
                    }
                    if let Some(pl) = prev_li {
                        if g.li <= pl {
                            return Err(format!("block ({q},{r}) rows not ascending"));
                        }
                    }
                    if g.li >= b.n_rows {
                        return Err(format!("block ({q},{r}) row {} out of stripe", g.li));
                    }
                    // Real prefix: in-bounds, strictly sorted columns.
                    let ps = g.pad_start as usize;
                    for k in ps..ps + g.len() {
                        let lj = b.cols[k];
                        if lj >= b.n_cols {
                            return Err(format!("block ({q},{r}) col {lj} out of stripe"));
                        }
                        if k > ps && b.cols[k - 1] >= lj {
                            return Err(format!("block ({q},{r}) cols not sorted"));
                        }
                    }
                    // Sentinel suffix: only on lane-eligible groups,
                    // fixed col/val so it can never encode data.
                    if g.padded_len() != g.len() && !g.lane_eligible() {
                        return Err(format!("block ({q},{r}) short group padded"));
                    }
                    for k in ps + g.len()..ps + g.padded_len() {
                        if b.cols[k] != SENTINEL_COL || b.vals[k] != 0.0 {
                            return Err(format!("block ({q},{r}) bad sentinel at {k}"));
                        }
                    }
                    prev_li = Some(g.li);
                    next = g.end;
                    pnext += g.padded_len();
                }
                if next as usize != b.nnz() {
                    return Err(format!("block ({q},{r}) groups cover {next} != {}", b.nnz()));
                }
                if pnext != b.padded_nnz() {
                    return Err(format!(
                        "block ({q},{r}) padded cover {pnext} != {}",
                        b.padded_nnz()
                    ));
                }
                // The sampling side table is optional; when present it
                // must agree with the binary search everywhere.
                if !b.entry_group.is_empty() {
                    if b.entry_group.len() != b.nnz() {
                        return Err(format!("block ({q},{r}) entry_group length"));
                    }
                    for k in 0..b.nnz() as u32 {
                        if b.entry_group[k as usize] as usize != b.group_of(k) {
                            return Err(format!("block ({q},{r}) entry_group[{k}] wrong"));
                        }
                    }
                }
                let lane_groups = b.groups.iter().filter(|g| g.lane_eligible()).count();
                if b.lane_groups as usize != lane_groups {
                    return Err(format!("block ({q},{r}) lane_groups count"));
                }
                // Cross-check content against the source matrix.
                let expect = self.block_entries(x, q, r);
                if expect.len() != b.nnz() {
                    return Err(format!("block ({q},{r}) entry count vs matrix"));
                }
                for g in &b.groups {
                    for (o, e) in expect[g.start as usize..g.end as usize].iter().enumerate() {
                        let k = g.pad_start as usize + o;
                        let gi = self.row_part.bounds[q] + g.li as usize;
                        let gj = self.col_part.bounds[r] + b.cols[k] as usize;
                        if gi != e.i as usize || gj != e.j as usize {
                            return Err(format!(
                                "block ({q},{r}) entry {k}: ({gi},{gj}) != ({},{})",
                                e.i, e.j
                            ));
                        }
                        if b.vals[k] != (e.x as f64 * inv_m) as f32 {
                            return Err(format!("block ({q},{r}) entry {k}: value drift"));
                        }
                    }
                }
            }
        }
        for r in 0..self.p {
            if !is_aligned(&self.inv_col32[r][..]) {
                return Err(format!("inv_col32[{r}] not 64B-aligned"));
            }
            for (lj, j) in self.col_part.block(r).enumerate() {
                let c = self.col_counts[j];
                let want = if c == 0 { 0.0 } else { 1.0 / c as f64 };
                if self.inv_col[r][lj] != want {
                    return Err(format!("inv_col[{r}][{lj}] wrong"));
                }
                if self.inv_col32[r][lj] != want as f32 {
                    return Err(format!("inv_col32[{r}][{lj}] wrong"));
                }
            }
        }
        for q in 0..self.p {
            for (li, i) in self.row_part.block(q).enumerate() {
                let c = self.row_counts[i];
                let want = if c == 0 { 0.0 } else { 1.0 / (self.m as f64 * c as f64) };
                if self.inv_row[q][li] != want {
                    return Err(format!("inv_row[{q}][{li}] wrong"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SparseSpec;
    use crate::util::prop;

    fn toy_matrix() -> Csr {
        Csr::from_rows(
            4,
            vec![
                vec![(0, 1.0), (3, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 4.0), (2, 5.0)],
                vec![(3, 6.0)],
                vec![(1, 7.0), (2, 8.0)],
            ],
        )
    }

    /// A matrix with one lane-eligible row (11 nonzeros → padded to 16)
    /// and one short row, for the padding-geometry tests.
    fn long_row_matrix() -> Csr {
        Csr::from_rows(
            16,
            vec![
                (0..11).map(|j| (j as u32, (j + 1) as f32)).collect(),
                vec![(2, 9.0), (7, 10.0), (12, 11.0)],
            ],
        )
    }

    #[test]
    fn build_places_entries_correctly() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        om.validate(&x).unwrap();
        // Rows 0..2 are stripe 0; cols 0..1 are stripe 0.
        // Ω^(0,0) = {(0,0,1.0), (1,1,3.0)} → local rows 0 and 1. All
        // groups are short, so physical == logical (pad_start = start).
        let b00 = om.block(0, 0);
        assert_eq!(b00.nnz(), 2);
        assert_eq!(b00.padded_nnz(), 2);
        assert!(!b00.has_lanes());
        assert_eq!(
            b00.groups,
            vec![
                RowGroup { li: 0, start: 0, end: 1, pad_start: 0 },
                RowGroup { li: 1, start: 1, end: 2, pad_start: 1 }
            ]
        );
        assert_eq!(b00.cols, vec![0, 1]);
        // Values are pre-scaled by 1/m (m = 5).
        assert_eq!(b00.vals, vec![(1.0f64 / 5.0) as f32, (3.0f64 / 5.0) as f32]);
        // Ω^(0,1) = {(0,3,2.0)} → local row 0, local col 1.
        let b01 = om.block(0, 1);
        assert_eq!(b01.groups, vec![RowGroup { li: 0, start: 0, end: 1, pad_start: 0 }]);
        assert_eq!(b01.cols, vec![1]);
        assert_eq!(b01.vals, vec![(2.0f64 / 5.0) as f32]);
    }

    #[test]
    fn counts_and_reciprocals_match_matrix() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        assert_eq!(om.row_counts, vec![2, 1, 2, 1, 2]);
        assert_eq!(om.col_counts, vec![2, 2, 2, 2]);
        assert_eq!(om.total_nnz(), x.nnz());
        // inv_col[r][lj] = 1/|Ω̄_j|, inv_row[q][li] = 1/(m|Ω_i|).
        assert_eq!(om.inv_col[0], vec![0.5, 0.5]);
        assert_eq!(om.inv_col[1], vec![0.5, 0.5]);
        assert_eq!(om.inv_col32[0], vec![0.5f32, 0.5]);
        assert_eq!(om.inv_row[0], vec![1.0 / 10.0, 1.0 / 5.0]);
        assert_eq!(om.inv_row[1], vec![1.0 / 10.0, 1.0 / 5.0, 1.0 / 10.0]);
    }

    #[test]
    fn lane_span_rounds_only_eligible_lengths() {
        assert_eq!(lane_span(0), 0);
        assert_eq!(lane_span(1), 1);
        assert_eq!(lane_span(LANES - 1), LANES - 1);
        assert_eq!(lane_span(LANES), LANES);
        assert_eq!(lane_span(LANES + 1), 2 * LANES);
        assert_eq!(lane_span(3 * LANES), 3 * LANES);
        assert_eq!(lane_span(3 * LANES + 5), 4 * LANES);
    }

    #[test]
    fn long_groups_are_sentinel_padded() {
        let x = long_row_matrix();
        let rp = Partition::even(2, 1);
        let cp = Partition::even(16, 1);
        let om = PackedBlocks::build(&x, &rp, &cp);
        om.validate(&x).unwrap();
        let b = om.block(0, 0);
        // Row 0 has 11 entries (lane-eligible, padded to 16); row 1 has
        // 3 (tight).
        assert_eq!(b.nnz(), 14);
        assert_eq!(b.padded_nnz(), 16 + 3);
        assert_eq!(b.lane_groups, 1);
        assert!(b.has_lanes());
        assert_eq!(
            b.groups,
            vec![
                RowGroup { li: 0, start: 0, end: 11, pad_start: 0 },
                RowGroup { li: 1, start: 11, end: 14, pad_start: 16 }
            ]
        );
        // Sentinel slots sit at physical 11..16 with col 0 / val 0.
        for k in 11..16 {
            assert_eq!(b.cols[k], SENTINEL_COL, "slot {k}");
            assert_eq!(b.vals[k], 0.0, "slot {k}");
        }
        // Real entries keep their order and values on both sides of
        // the padding.
        assert_eq!(&b.cols[..11], &(0..11).collect::<Vec<u32>>()[..]);
        assert_eq!(&b.cols[16..], &[2, 7, 12]);
        assert_eq!(b.vals[16], (9.0f64 / 2.0) as f32);
    }

    #[test]
    fn aligned_storage_after_build() {
        // §Alignment regression guard: every block's lane storage
        // (cols/vals — the arrays holding the lane regions) and every
        // per-stripe gather table (inv_col32, stripe_alpha_bias) must
        // start 64-byte aligned after `build`, on tight and padded
        // layouts alike — the explicit-SIMD backend's base-address
        // contract (simd::aligned).
        for (x, m, d) in [(toy_matrix(), 5, 4), (long_row_matrix(), 2, 16)] {
            let p = 2.min(m).min(d);
            let rp = Partition::even(m, p);
            let cp = Partition::even(d, p);
            let om = PackedBlocks::build(&x, &rp, &cp);
            for q in 0..p {
                for r in 0..p {
                    let b = om.block(q, r);
                    assert!(is_aligned(&b.cols[..]), "block ({q},{r}) cols");
                    assert!(is_aligned(&b.vals[..]), "block ({q},{r}) vals");
                }
            }
            for r in 0..p {
                assert!(is_aligned(&om.inv_col32[r][..]), "inv_col32[{r}]");
            }
            let y = vec![1.0f32; m];
            let bias = om.stripe_alpha_bias(&y);
            for q in 0..p {
                assert!(is_aligned(&bias[q][..]), "stripe_alpha_bias[{q}]");
            }
            // validate() enforces the same contract (defense in depth
            // for hand-assembled blocks in tests).
            om.validate(&x).unwrap();
        }
    }

    #[test]
    fn entry_group_matches_group_of_and_physical_mapping() {
        let x = long_row_matrix();
        let rp = Partition::even(2, 1);
        let cp = Partition::even(16, 1);
        // Default build keeps the cold side table empty (it is pure
        // overhead for full sweeps); the lookup falls back to the
        // binary search and the physical mapping still works.
        let lean = PackedBlocks::build(&x, &rp, &cp);
        assert!(lean.block(0, 0).entry_group.is_empty());
        assert_eq!(lean.block(0, 0).physical_of(11), 16);
        let om = lean.with_sampling_tables();
        om.validate(&x).unwrap();
        let b = om.block(0, 0);
        for k in 0..b.nnz() as u32 {
            let gi = b.entry_group[k as usize] as usize;
            assert_eq!(gi, b.group_of(k), "entry {k}");
            assert_eq!(gi, b.group_of_cached(k), "entry {k} (cached)");
            let g = &b.groups[gi];
            let kp = b.physical_of(k);
            assert!(kp >= g.pad_start as usize && kp < g.pad_start as usize + g.len());
            // The physical slot is never a sentinel.
            assert!(b.vals[kp] != 0.0 || b.cols[kp] != SENTINEL_COL || k == 0);
        }
        // Logical entry 11 (first of row 1) maps past the padding.
        assert_eq!(b.physical_of(11), 16);
    }

    #[test]
    fn groups_ascending_and_cols_sorted() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        for q in 0..2 {
            for r in 0..2 {
                let b = om.block(q, r);
                for gk in 1..b.groups.len() {
                    assert!(b.groups[gk - 1].li < b.groups[gk].li, "block ({q},{r})");
                }
                for g in &b.groups {
                    let ps = g.pad_start as usize;
                    for k in ps + 1..ps + g.len() {
                        assert!(b.cols[k - 1] < b.cols[k], "block ({q},{r}) cols");
                    }
                }
            }
        }
    }

    #[test]
    fn group_of_finds_owning_row() {
        let x = toy_matrix();
        let rp = Partition::even(5, 1);
        let cp = Partition::even(4, 1);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let b = om.block(0, 0);
        for (gi, g) in b.groups.iter().enumerate() {
            for k in g.start..g.end {
                assert_eq!(b.group_of(k), gi, "entry {k}");
            }
        }
    }

    #[test]
    fn block_entries_reconstruct_exact_values() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let e00 = om.block_entries(&x, 0, 0);
        assert_eq!(
            e00,
            vec![Entry { i: 0, j: 0, x: 1.0 }, Entry { i: 1, j: 1, x: 3.0 }]
        );
        assert_eq!(om.block_entries(&x, 0, 1), vec![Entry { i: 0, j: 3, x: 2.0 }]);
        let total: usize =
            (0..2).flat_map(|q| (0..2).map(move |r| (q, r)))
                .map(|(q, r)| om.block_entries(&x, q, r).len())
                .sum();
        assert_eq!(total, x.nnz());
    }

    #[test]
    fn stripe_alpha_bias_is_label_times_inv_row() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let y = [1.0f32, -1.0, 1.0, -1.0, 1.0];
        let bias = om.stripe_alpha_bias(&y);
        assert_eq!(bias.len(), 2);
        for q in 0..2 {
            assert_eq!(bias[q].len(), rp.block_len(q));
            for (li, i) in rp.block(q).enumerate() {
                assert_eq!(
                    bias[q][li],
                    (y[i] as f64 * om.inv_row[q][li]) as f32,
                    "stripe {q} row {li}"
                );
            }
        }
        // Spot value: row 0 has |Ω_0| = 2, m = 5 → bias = 1/(5·2).
        assert_eq!(bias[0][0], (1.0f64 / 10.0) as f32);
        assert_eq!(bias[0][1], (-1.0f64 / 5.0) as f32);
    }

    #[test]
    fn stripe_labels_follow_row_partition() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 2);
        let om = PackedBlocks::build(&x, &rp, &cp);
        let y = [1.0f32, -1.0, 1.0, -1.0, 1.0];
        let yl = om.stripe_labels(&y);
        assert_eq!(yl[0], vec![1.0, -1.0]);
        assert_eq!(yl[1], vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn prop_blocks_cover_and_are_disjoint() {
        prop::check("omega blocks", 50, |g| {
            let m = g.usize_in(2, 80);
            let d = g.usize_in(2, 60);
            let p = g.usize_in(1, 6.min(m).min(d));
            // nnz_per_row spans both sides of LANES so the lane-padding
            // invariants are exercised alongside the tight layout.
            let ds = SparseSpec {
                name: "prop".into(),
                m,
                d,
                nnz_per_row: g.f64_in(1.0, 14.0),
                zipf_s: g.f64_in(0.0, 1.2),
                label_noise: 0.0,
                pos_frac: 0.5,
                seed: g.case_seed,
            }
            .generate();
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            // Validate both with and without the sampling side tables.
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            om.validate(&ds.x).map_err(|e| e)?;
            let om = om.with_sampling_tables();
            om.validate(&ds.x).map_err(|e| e)?;
            prop::assert_that(om.epoch_imbalance() >= 0.99, "imbalance >= 1")
        });
    }

    #[test]
    fn imbalance_perfect_on_uniform_diagonal() {
        // Diagonal matrix, p = n: all entries are on the r=0 diagonal:
        // epoch cost = 1 (r=0) + 0 + 0, ideal = 1 -> imbalance 1.0.
        let x = Csr::from_rows(3, vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(2, 1.0)]]);
        let rp = Partition::even(3, 3);
        let cp = Partition::even(3, 3);
        let om = PackedBlocks::build(&x, &rp, &cp);
        assert!((om.epoch_imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "equal p")]
    fn mismatched_p_panics() {
        let x = toy_matrix();
        let rp = Partition::even(5, 2);
        let cp = Partition::even(4, 3);
        PackedBlocks::build(&x, &rp, &cp);
    }
}
