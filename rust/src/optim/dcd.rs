//! Dual coordinate descent (LIBLINEAR-style, Hsieh et al. 2008) for
//! L2-regularized hinge loss.
//!
//! App. B: "In parallel experiments, each MPI process executed dual
//! coordinate descent on its local data to locally initialize w_j and
//! α_i parameters; then w_j values were averaged across all machines."
//! This module provides that warm start, and doubles as a high-accuracy
//! reference solver for small problems in the tests (its optimum is the
//! ground truth the stochastic solvers are compared against).
//!
//! Mapping to the paper's parameterization: our objective is
//! λ‖w‖² + (1/m)Σ hinge, equivalent to LIBLINEAR's ½‖w‖² + C Σ hinge
//! with C = 1/(2λm) after rescaling; the DSO dual variable relates to
//! LIBLINEAR's ᾱ_i ∈ [0, C] by α_i = y_i ᾱ_i / C ∈ y_i·[0, 1].

use crate::data::Dataset;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct DcdResult {
    pub w: Vec<f32>,
    /// DSO-parameterized dual variables (β = yα ∈ [0,1]).
    pub alpha: Vec<f32>,
    pub epochs_run: usize,
    /// Maximum projected-gradient violation on the last epoch.
    pub max_violation: f64,
}

/// Run DCD for at most `epochs` passes (random permutation each pass),
/// stopping early when the projected gradient violation drops below
/// `tol`.
pub fn solve_hinge_l2(
    ds: &Dataset,
    lambda: f64,
    epochs: usize,
    tol: f64,
    seed: u64,
) -> DcdResult {
    let m = ds.m();
    let d = ds.d();
    let c_upper = 1.0 / (2.0 * lambda * m as f64);

    // Q_ii = ⟨x_i, x_i⟩ (in LIBLINEAR's scaled space the same).
    let qii: Vec<f64> = (0..m)
        .map(|i| {
            let (_, vals) = ds.x.row(i);
            vals.iter().map(|&v| v as f64 * v as f64).sum()
        })
        .collect();

    let mut w = vec![0f32; d];
    let mut abar = vec![0f64; m]; // LIBLINEAR alphas in [0, C]
    let mut order: Vec<usize> = (0..m).collect();
    let mut rng = Xoshiro256::new(seed);
    let mut epochs_run = 0;
    let mut max_violation = f64::INFINITY;

    for _ in 0..epochs {
        rng.shuffle(&mut order);
        max_violation = 0.0;
        for &i in &order {
            if qii[i] <= 0.0 {
                continue;
            }
            let y = ds.y[i] as f64;
            let g = y * ds.x.row_dot(i, &w) - 1.0; // ∇_i dual
            let a = abar[i];
            // Projected gradient.
            let pg = if a <= 0.0 {
                g.min(0.0)
            } else if a >= c_upper {
                g.max(0.0)
            } else {
                g
            };
            max_violation = max_violation.max(pg.abs());
            if pg.abs() > 1e-14 {
                let a_new = (a - g / qii[i]).clamp(0.0, c_upper);
                let delta = a_new - a;
                if delta != 0.0 {
                    abar[i] = a_new;
                    let (idx, val) = ds.x.row(i);
                    let step = (delta * y) as f32;
                    for k in 0..idx.len() {
                        w[idx[k] as usize] += step * val[k];
                    }
                }
            }
        }
        epochs_run += 1;
        if max_violation < tol {
            break;
        }
    }

    // Convert to DSO dual parameterization: α_i = y_i ᾱ_i / C.
    let alpha: Vec<f32> =
        (0..m).map(|i| (ds.y[i] as f64 * abar[i] / c_upper) as f32).collect();
    DcdResult { w, alpha, epochs_run, max_violation }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::data::synth::SparseSpec;
    use crate::losses::{Loss, Problem, Regularizer};

    fn small_ds() -> Dataset {
        SparseSpec {
            name: "dcd-test".into(),
            m: 200,
            d: 50,
            nnz_per_row: 8.0,
            zipf_s: 0.8,
            label_noise: 0.05,
            pos_frac: 0.5,
            seed: 21,
        }
        .generate()
    }

    #[test]
    fn converges_and_alpha_feasible() {
        let ds = small_ds();
        let lambda = 1e-3;
        let r = solve_hinge_l2(&ds, lambda, 200, 1e-8, 1);
        // f32 weight storage bounds the reachable KKT accuracy.
        assert!(r.max_violation < 1e-4, "violation {}", r.max_violation);
        for (i, &a) in r.alpha.iter().enumerate() {
            let beta = ds.y[i] as f64 * a as f64;
            assert!((-1e-6..=1.0 + 1e-6).contains(&beta), "β_{i} = {beta}");
        }
    }

    #[test]
    fn primal_dual_gap_near_zero_at_solution() {
        let ds = small_ds();
        let lambda = 1e-3;
        let r = solve_hinge_l2(&ds, lambda, 500, 1e-10, 1);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, lambda);
        let gap = p.duality_gap(&ds, &r.w, &r.alpha);
        let primal = p.primal(&ds, &r.w);
        assert!(
            gap.abs() / primal.max(1e-9) < 1e-3,
            "relative gap {} (primal {primal})",
            gap / primal
        );
    }

    /// w must equal the conjugate minimizer of its own dual variables —
    /// the invariant that DCD maintains incrementally.
    #[test]
    fn w_consistent_with_alpha() {
        let ds = small_ds();
        let lambda = 1e-2;
        let r = solve_hinge_l2(&ds, lambda, 100, 1e-8, 3);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, lambda);
        let w_rec = p.w_from_alpha(&ds, &r.alpha);
        for j in 0..ds.d() {
            assert!(
                (w_rec[j] - r.w[j]).abs() < 1e-4,
                "coord {j}: {} vs {}",
                w_rec[j],
                r.w[j]
            );
        }
    }

    #[test]
    fn improves_over_zero() {
        let ds = small_ds();
        let lambda = 1e-3;
        let p = Problem::new(Loss::Hinge, Regularizer::L2, lambda);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        let r = solve_hinge_l2(&ds, lambda, 50, 1e-8, 1);
        let at_sol = p.primal(&ds, &r.w);
        assert!(at_sol < at_zero * 0.9, "{at_sol} !< {at_zero}");
    }

    #[test]
    fn separable_problem_reaches_zero_loss() {
        // Trivially separable: x = y * e_0.
        let x = Csr::from_rows(
            2,
            vec![vec![(0, 1.0)], vec![(0, -1.0)], vec![(0, 1.0)], vec![(0, -1.0)]],
        );
        let ds = Dataset::new("sep", x, vec![1.0, -1.0, 1.0, -1.0]);
        let r = solve_hinge_l2(&ds, 1e-4, 1000, 1e-10, 5);
        assert_eq!(ds.test_error(&r.w), 0.0);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-4);
        assert!(p.primal(&ds, &r.w) < 0.05);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = small_ds();
        let a = solve_hinge_l2(&ds, 1e-3, 20, 0.0, 7);
        let b = solve_hinge_l2(&ds, 1e-3, 20, 0.0, 7);
        assert_eq!(a.w, b.w);
        assert_eq!(a.alpha, b.alpha);
    }

    #[test]
    fn empty_rows_are_skipped() {
        let x = Csr::from_rows(1, vec![vec![], vec![(0, 1.0)]]);
        let ds = Dataset::new("e", x, vec![1.0, 1.0]);
        let r = solve_hinge_l2(&ds, 0.1, 10, 1e-8, 1);
        assert_eq!(r.alpha[0], 0.0);
        assert!(r.w[0] > 0.0);
    }
}
