//! Optimization machinery: step-size schedules (η₀/√t of Algorithm 1,
//! AdaGrad of App. B), the LIBLINEAR-style dual coordinate descent used
//! for warm starts, and the simplex QP solver behind BMRM.

pub mod dcd;
pub mod qp;
pub mod step;

pub use step::{AdaGrad, Schedule, Stepper};
