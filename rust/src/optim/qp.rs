//! Simplex-constrained QP solver for the BMRM inner problem.
//!
//! BMRM (Teo et al., JMLR 2010) with an L2 regularizer solves, at every
//! iteration, the dual of its cutting-plane model:
//!
//! ```text
//!     max_β  bᵀβ − (1/4λ) βᵀGβ    s.t.  β ≥ 0, Σβ = 1,
//! ```
//!
//! where G_kl = ⟨a_k, a_l⟩ is the Gram matrix of subgradients. This
//! module solves the equivalent minimization
//!
//! ```text
//!     min_β  ½ βᵀHβ − bᵀβ,   H = G/(2λ),
//! ```
//!
//! by projected gradient with a Lipschitz step and Duchi et al.'s O(n
//! log n) Euclidean projection onto the simplex. Problem sizes are tiny
//! (n = number of cutting planes, ≤ a few hundred), so robustness beats
//! cleverness here.

/// Euclidean projection of v onto the probability simplex
/// {β : β ≥ 0, Σβ = 1} (Duchi, Shalev-Shwartz, Singer, Chandra 2008).
pub fn project_simplex(v: &[f64]) -> Vec<f64> {
    let n = v.len();
    assert!(n > 0);
    let mut u = v.to_vec();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut rho = 0;
    let mut theta = 0.0;
    for (k, &uk) in u.iter().enumerate() {
        css += uk;
        let t = (css - 1.0) / (k + 1) as f64;
        if uk - t > 0.0 {
            rho = k + 1;
            theta = t;
        }
    }
    let _ = rho;
    v.iter().map(|&x| (x - theta).max(0.0)).collect()
}

/// Result of a QP solve.
#[derive(Clone, Debug)]
pub struct QpSolution {
    pub beta: Vec<f64>,
    /// Objective value bᵀβ − ¼λ⁻¹ βᵀGβ at the solution (the *max* form).
    pub value: f64,
    pub iterations: usize,
    /// Max KKT violation at exit (projected-gradient norm).
    pub kkt_residual: f64,
}

/// Solve max_β bᵀβ − (1/4λ)βᵀGβ over the simplex.
///
/// `gram[k][l]` must be ⟨a_k, a_l⟩ (symmetric PSD). Converges to
/// `tol` on the projected-gradient residual or stops at `max_iter`.
pub fn solve_bmrm_dual(
    gram: &[Vec<f64>],
    b: &[f64],
    lambda: f64,
    tol: f64,
    max_iter: usize,
) -> QpSolution {
    let n = b.len();
    assert_eq!(gram.len(), n);
    assert!(lambda > 0.0);
    if n == 1 {
        let beta = vec![1.0];
        let value = b[0] - gram[0][0] / (4.0 * lambda);
        return QpSolution { beta, value, iterations: 0, kkt_residual: 0.0 };
    }

    // H = G/(2λ). Lipschitz constant of ∇(½βᵀHβ − bᵀβ) is ‖H‖₂ ≤
    // max_k Σ_l |H_kl| (infinity norm bound, fine at these sizes).
    let scale = 1.0 / (2.0 * lambda);
    let mut lip: f64 = 0.0;
    for k in 0..n {
        let row: f64 = gram[k].iter().map(|x| x.abs() * scale).sum();
        lip = lip.max(row);
    }
    let step = if lip > 0.0 { 1.0 / lip } else { 1.0 };

    // Start uniform.
    let mut beta = vec![1.0 / n as f64; n];
    let mut grad = vec![0.0; n];
    let mut resid = f64::INFINITY;
    let mut it = 0;
    while it < max_iter {
        // grad = Hβ − b.
        for k in 0..n {
            let mut s = 0.0;
            for l in 0..n {
                s += gram[k][l] * beta[l];
            }
            grad[k] = s * scale - b[k];
        }
        let cand: Vec<f64> =
            (0..n).map(|k| beta[k] - step * grad[k]).collect();
        let next = project_simplex(&cand);
        resid = (0..n)
            .map(|k| (next[k] - beta[k]).abs())
            .fold(0.0, f64::max)
            / step;
        beta = next;
        it += 1;
        if resid < tol {
            break;
        }
    }

    // Value in the max form.
    let mut quad = 0.0;
    for k in 0..n {
        for l in 0..n {
            quad += beta[k] * gram[k][l] * beta[l];
        }
    }
    let value =
        b.iter().zip(&beta).map(|(bi, bv)| bi * bv).sum::<f64>() - quad / (4.0 * lambda);
    QpSolution { beta, value, iterations: it, kkt_residual: resid }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn simplex_projection_properties() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..200 {
            let n = 1 + rng.gen_index(8);
            let v: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let p = project_simplex(&v);
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn simplex_projection_identity_on_simplex() {
        let v = vec![0.2, 0.3, 0.5];
        let p = project_simplex(&v);
        for (a, b) in v.iter().zip(&p) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn simplex_projection_is_nearest_point() {
        // Check against brute-force grid on 2 dims: project (x, y),
        // nearest point on the segment β0+β1=1, β≥0.
        let v = [1.7, -0.4];
        let p = project_simplex(&v);
        let mut best = (0.0, f64::INFINITY);
        for k in 0..=1000 {
            let b0 = k as f64 / 1000.0;
            let b1 = 1.0 - b0;
            let d = (v[0] - b0).powi(2) + (v[1] - b1).powi(2);
            if d < best.1 {
                best = (b0, d);
            }
        }
        assert!((p[0] - best.0).abs() < 2e-3, "{} vs {}", p[0], best.0);
    }

    #[test]
    fn single_plane_trivial() {
        let sol = solve_bmrm_dual(&[vec![2.0]], &[3.0], 0.5, 1e-9, 100);
        assert_eq!(sol.beta, vec![1.0]);
        assert!((sol.value - (3.0 - 2.0 / 2.0)).abs() < 1e-12);
    }

    #[test]
    fn matches_brute_force_two_planes() {
        // a1 = (1, 0), a2 = (0, 2) → G = [[1,0],[0,4]].
        let gram = vec![vec![1.0, 0.0], vec![0.0, 4.0]];
        let b = vec![0.5, 1.0];
        let lambda = 0.25;
        let sol = solve_bmrm_dual(&gram, &b, lambda, 1e-10, 10_000);
        // Brute force over the simplex.
        let mut best = f64::NEG_INFINITY;
        let mut best_b0 = 0.0;
        for k in 0..=100_000 {
            let b0 = k as f64 / 100_000.0;
            let b1 = 1.0 - b0;
            let quad = b0 * b0 * 1.0 + b1 * b1 * 4.0;
            let v = 0.5 * b0 + 1.0 * b1 - quad / (4.0 * lambda);
            if v > best {
                best = v;
                best_b0 = b0;
            }
        }
        assert!((sol.value - best).abs() < 1e-6, "{} vs {best}", sol.value);
        assert!((sol.beta[0] - best_b0).abs() < 1e-3);
    }

    #[test]
    fn random_psd_problems_satisfy_kkt() {
        let mut rng = Xoshiro256::new(9);
        for _ in 0..20 {
            let n = 2 + rng.gen_index(6);
            let dim = 3 + rng.gen_index(5);
            // Random subgradient vectors → PSD Gram.
            let a: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect())
                .collect();
            let gram: Vec<Vec<f64>> = (0..n)
                .map(|k| {
                    (0..n)
                        .map(|l| a[k].iter().zip(&a[l]).map(|(x, y)| x * y).sum())
                        .collect()
                })
                .collect();
            let b: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let lambda = rng.uniform(0.05, 1.0);
            let sol = solve_bmrm_dual(&gram, &b, lambda, 1e-9, 50_000);
            assert!(sol.kkt_residual < 1e-6, "residual {}", sol.kkt_residual);
            // Value must beat every vertex within tolerance.
            for k in 0..n {
                let v = b[k] - gram[k][k] / (4.0 * lambda);
                assert!(sol.value >= v - 1e-7, "vertex {k}: {v} > {}", sol.value);
            }
            // And every random feasible point.
            for _ in 0..50 {
                let r: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
                let beta = project_simplex(&r);
                let mut quad = 0.0;
                for k in 0..n {
                    for l in 0..n {
                        quad += beta[k] * gram[k][l] * beta[l];
                    }
                }
                let v = b.iter().zip(&beta).map(|(x, y)| x * y).sum::<f64>()
                    - quad / (4.0 * lambda);
                assert!(sol.value >= v - 1e-7);
            }
        }
    }
}
