//! Step-size machinery.
//!
//! Algorithm 1 uses η_t = η₀/√t per epoch; the experiments (App. B)
//! use AdaGrad [Duchi et al.] per-coordinate adaptation. Both are
//! provided; AdaGrad is the default as in the paper.

use crate::config::StepKind;

/// Epoch-level scalar schedule.
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Const { eta0: f64 },
    /// η_t = η₀ / √t (t = epoch, 1-based) — the schedule of Theorem 1.
    InvSqrt { eta0: f64 },
}

impl Schedule {
    pub fn eta(&self, epoch: usize) -> f64 {
        match *self {
            Schedule::Const { eta0 } => eta0,
            Schedule::InvSqrt { eta0 } => eta0 / ((epoch.max(1)) as f64).sqrt(),
        }
    }
}

/// Per-coordinate AdaGrad state: η_j = η₀ / √(ε + Σ g²).
///
/// The accumulators for the `w` coordinates travel with the `w` block
/// in DSO's ring rotation (they are part of the coordinate's state),
/// while the α accumulators stay put with their owner.
#[derive(Clone, Debug)]
pub struct AdaGrad {
    pub eta0: f64,
    pub accum: Vec<f32>,
}

pub const ADAGRAD_EPS: f64 = 1e-8;

impl AdaGrad {
    pub fn new(n: usize, eta0: f64) -> AdaGrad {
        assert!(eta0 > 0.0);
        AdaGrad { eta0, accum: vec![0.0; n] }
    }

    /// Accumulate g² for coordinate `j` and return the step size to use
    /// for this update.
    #[inline]
    pub fn step(&mut self, j: usize, g: f64) -> f64 {
        let a = self.accum[j] as f64 + g * g;
        self.accum[j] = a as f32;
        self.eta0 / (ADAGRAD_EPS + a).sqrt()
    }

    /// Read-only current step size (no accumulation).
    #[inline]
    pub fn current(&self, j: usize) -> f64 {
        self.eta0 / (ADAGRAD_EPS + self.accum[j] as f64).sqrt()
    }

    pub fn len(&self) -> usize {
        self.accum.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accum.is_empty()
    }
}

/// Per-coordinate adaptive state η_j = η₀ / √(1 + Σ g²) — the
/// Cutkosky & Busa-Fekete (arXiv:1802.05811) style rate: AdaGrad's
/// accumulated second-moment statistic with a unit offset inside the
/// root instead of the ε floor, so the step is bounded by η₀ from the
/// very first update (no 1/√ε blow-up on fresh sparse coordinates).
/// Accumulators travel with their coordinates exactly like AdaGrad's.
#[derive(Clone, Debug)]
pub struct Adaptive {
    pub eta0: f64,
    pub accum: Vec<f32>,
}

impl Adaptive {
    pub fn new(n: usize, eta0: f64) -> Adaptive {
        assert!(eta0 > 0.0);
        Adaptive { eta0, accum: vec![0.0; n] }
    }

    /// Accumulate g² for coordinate `j` and return the step size.
    #[inline]
    pub fn step(&mut self, j: usize, g: f64) -> f64 {
        let a = self.accum[j] as f64 + g * g;
        self.accum[j] = a as f32;
        self.eta0 / (1.0 + a).sqrt()
    }
}

/// Unified stepper used by the scalar update loop: a shared scalar
/// η_t, or per-coordinate AdaGrad/Adaptive state.
#[derive(Clone, Debug)]
pub enum Stepper {
    Scalar(Schedule),
    AdaGrad(AdaGrad),
    Adaptive(Adaptive),
}

impl Stepper {
    pub fn new(kind: StepKind, n: usize, eta0: f64) -> Stepper {
        match kind {
            StepKind::Const => Stepper::Scalar(Schedule::Const { eta0 }),
            StepKind::InvSqrt => Stepper::Scalar(Schedule::InvSqrt { eta0 }),
            StepKind::AdaGrad => Stepper::AdaGrad(AdaGrad::new(n, eta0)),
            StepKind::Adaptive => Stepper::Adaptive(Adaptive::new(n, eta0)),
        }
    }

    /// Step size for coordinate `j` with incoming gradient `g` at epoch
    /// `t` (1-based). The accumulator rules accumulate; scalar
    /// schedules ignore j, g.
    #[inline]
    pub fn step(&mut self, j: usize, g: f64, epoch: usize) -> f64 {
        match self {
            Stepper::Scalar(s) => s.eta(epoch),
            Stepper::AdaGrad(a) => a.step(j, g),
            Stepper::Adaptive(a) => a.step(j, g),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invsqrt_schedule() {
        let s = Schedule::InvSqrt { eta0: 2.0 };
        assert!((s.eta(1) - 2.0).abs() < 1e-12);
        assert!((s.eta(4) - 1.0).abs() < 1e-12);
        assert!((s.eta(100) - 0.2).abs() < 1e-12);
        // Guard t = 0.
        assert!((s.eta(0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn const_schedule() {
        let s = Schedule::Const { eta0: 0.5 };
        assert_eq!(s.eta(1), 0.5);
        assert_eq!(s.eta(1000), 0.5);
    }

    #[test]
    fn adagrad_decreases_with_gradient_mass() {
        let mut a = AdaGrad::new(2, 1.0);
        let e1 = a.step(0, 1.0);
        let e2 = a.step(0, 1.0);
        let e3 = a.step(0, 1.0);
        assert!(e1 > e2 && e2 > e3);
        assert!((e1 - 1.0).abs() < 1e-4); // 1/sqrt(1)
        assert!((e2 - 1.0 / 2f64.sqrt()).abs() < 1e-4);
        // Other coordinate untouched.
        assert_eq!(a.accum[1], 0.0);
    }

    #[test]
    fn adagrad_per_coordinate_independent() {
        let mut a = AdaGrad::new(2, 1.0);
        for _ in 0..10 {
            a.step(0, 2.0);
        }
        let big = a.current(0);
        let fresh = a.current(1);
        assert!(fresh > big * 5.0);
    }

    #[test]
    fn adagrad_zero_grad_keeps_step() {
        let mut a = AdaGrad::new(1, 1.0);
        let e = a.step(0, 0.0);
        assert!(e > 1e3); // 1/sqrt(eps)
        assert_eq!(a.accum[0], 0.0);
    }

    #[test]
    fn adaptive_is_bounded_by_eta0_and_decreasing() {
        let mut a = Adaptive::new(2, 0.5);
        // First step: 0.5/√(1+g²) ≤ 0.5 — never the 1/√ε blow-up.
        let e1 = a.step(0, 0.0);
        assert!((e1 - 0.5).abs() < 1e-12);
        let e2 = a.step(0, 1.0);
        let e3 = a.step(0, 1.0);
        assert!(e2 > e3);
        assert!((e2 - 0.5 / 2f64.sqrt()).abs() < 1e-9);
        assert_eq!(a.accum[1], 0.0);
    }

    #[test]
    fn stepper_dispatch() {
        let mut s = Stepper::new(StepKind::InvSqrt, 4, 1.0);
        assert!((s.step(0, 123.0, 4) - 0.5).abs() < 1e-12);
        let mut s = Stepper::new(StepKind::AdaGrad, 4, 1.0);
        let e1 = s.step(2, 1.0, 1);
        let e2 = s.step(2, 1.0, 1);
        assert!(e2 < e1);
        let mut s = Stepper::new(StepKind::Const, 4, 0.25);
        assert_eq!(s.step(3, 9.0, 77), 0.25);
    }
}
