//! Request batch packing — the serving twin of the training
//! `PackedBlocks` layout.
//!
//! A predict batch is packed row-major into the same lane-major SoA
//! shape the sweep kernels consume: one [`RowGroup`] per request row
//! (`li` = request index), column ids and feature values in §Alignment
//! 64-byte-aligned [`AVec`] storage, lane-eligible groups padded to
//! `LANES` multiples with read-only sentinel slots (`col =
//! SENTINEL_COL`, `val = 0.0`). Two deliberate differences from the
//! training layout:
//!
//! * Column ids are **global** (no column stripes — serving gathers
//!   against the full w), and values are the **raw** features, not the
//!   sweep's pre-scaled x/m: the batched fold must reproduce
//!   `Csr::row_dot` bit for bit.
//! * Empty request rows keep their (zero-length) group, so the packer
//!   emits exactly one group — and the kernel exactly one score — per
//!   request, in request order.

use crate::data::sparse::Csr;
use crate::partition::omega::{lane_span, RowGroup, LANES, SENTINEL_COL};
use crate::simd::AVec;

/// A batch of predict requests in lane-major packed form.
#[derive(Clone, Debug)]
pub struct PackedRequests {
    /// One group per request row, ascending `li` = 0..n_requests.
    pub groups: Vec<RowGroup>,
    /// Global column id per physical slot; sentinel slots hold
    /// [`SENTINEL_COL`]. 64-byte-aligned ([`AVec`]).
    pub cols: AVec<u32>,
    /// Raw feature value per physical slot (NOT x/m-scaled — serving
    /// reproduces `Csr::row_dot`); sentinel slots hold 0.0.
    pub vals: AVec<f32>,
    /// Model dimension every column id was validated against.
    pub d: usize,
}

impl PackedRequests {
    /// Number of request rows (== number of scores produced).
    #[inline]
    pub fn n_requests(&self) -> usize {
        self.groups.len()
    }

    /// Number of real entries (sentinel padding excluded).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.groups.last().map_or(0, |g| g.end as usize)
    }

    /// Physical storage slots, including sentinel padding.
    #[inline]
    pub fn padded_nnz(&self) -> usize {
        self.vals.len()
    }

    /// Pack the rows of a CSR matrix against a model of dimension `d`.
    ///
    /// Refuses batches whose features don't fit the model: every
    /// column id must be `< d` (the serving-side dimension-mismatch
    /// contract — a request for feature j ≥ d has no weight to gather),
    /// and `d` must fit the AVX2 gather's sign-extending i32 indices.
    /// `x.cols <= d` is allowed: libsvm omits trailing zero features,
    /// so a request batch routinely parses narrower than the model.
    pub fn pack(x: &Csr, d: usize) -> Result<PackedRequests, String> {
        if d > i32::MAX as usize {
            return Err(format!(
                "model dimension {d} exceeds the SIMD gather index range ({})",
                i32::MAX
            ));
        }
        if x.cols > d {
            return Err(format!(
                "request batch uses {} features but the model has {d}; \
                 retrain with the widened data (Trainer::fit_from) or fix the request",
                x.cols
            ));
        }
        let mut groups = Vec::with_capacity(x.rows);
        let padded: usize = (0..x.rows).map(|i| lane_span(x.row_nnz(i))).sum();
        let mut cols = AVec::with_capacity(padded);
        let mut vals = AVec::with_capacity(padded);
        let mut logical = 0u32;
        for i in 0..x.rows {
            let (idx, val) = x.row(i);
            let g = RowGroup {
                li: i as u32,
                start: logical,
                end: logical + idx.len() as u32,
                pad_start: cols.len() as u32,
            };
            // Storage order inside the row is preserved verbatim from
            // the CSR row — the fold's f64 accumulation order (hence
            // bitwise identity with row_dot) depends on it.
            cols.extend_from_slice(idx);
            vals.extend_from_slice(val);
            for _ in idx.len()..g.padded_len() {
                cols.push(SENTINEL_COL);
                vals.push(0.0);
            }
            logical = g.end;
            groups.push(g);
        }
        Ok(PackedRequests { groups, cols, vals, d })
    }

    /// Structural invariants, mirroring `PackedBlocks::validate`:
    /// groups tile the logical and physical ranges in request order,
    /// every real column id is `< d`, sentinel slots are inert, and
    /// the lane storage honors the §Alignment contract. O(padded_nnz);
    /// used by tests and debug assertions, not the request hot path
    /// (the kernel re-checks the cheap bounds itself).
    pub fn validate(&self) -> Result<(), String> {
        let mut logical = 0u32;
        let mut physical = 0usize;
        for (i, g) in self.groups.iter().enumerate() {
            if g.li as usize != i {
                return Err(format!("group {i} carries li {}", g.li));
            }
            if g.start != logical || g.end < g.start {
                return Err(format!("group {i} logical range not tiled"));
            }
            if g.pad_start as usize != physical {
                return Err(format!("group {i} physical region not tiled"));
            }
            for k in 0..g.padded_len() {
                let kp = g.pad_start as usize + k;
                if k < g.len() {
                    if self.cols[kp] as usize >= self.d {
                        return Err(format!(
                            "request {i} feature {} out of model range {}",
                            self.cols[kp], self.d
                        ));
                    }
                } else if self.cols[kp] != SENTINEL_COL || self.vals[kp] != 0.0 {
                    return Err(format!("request {i} sentinel slot {kp} not inert"));
                }
            }
            logical = g.end;
            physical += g.padded_len();
        }
        if physical != self.padded_nnz() || self.cols.len() != self.vals.len() {
            return Err("physical regions do not tile storage".into());
        }
        if !crate::simd::is_aligned(&self.cols[..]) || !crate::simd::is_aligned(&self.vals[..]) {
            return Err("packed request storage violates the §Alignment contract".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> Csr {
        // Rows: lane-eligible (10 entries → padded to 16), short (2),
        // empty (0), exactly one lane (8).
        let rows: Vec<Vec<(u32, f32)>> = vec![
            (0..10).map(|j| (j as u32, 0.5 + j as f32)).collect(),
            vec![(3, -1.0), (7, 2.0)],
            vec![],
            (2..10).map(|j| (j as u32, j as f32)).collect(),
        ];
        Csr::from_rows(12, rows)
    }

    #[test]
    fn pack_tiles_groups_and_pads_ragged_tails() {
        let x = batch();
        let p = PackedRequests::pack(&x, 12).unwrap();
        p.validate().unwrap();
        assert_eq!(p.n_requests(), 4);
        assert_eq!(p.nnz(), x.nnz());
        // 10 → 16, 2 → 2, 0 → 0, 8 → 8.
        assert_eq!(p.padded_nnz(), 16 + 2 + 8);
        assert_eq!(p.groups[0].padded_len(), 2 * LANES);
        assert!(p.groups[0].lane_eligible());
        assert!(!p.groups[1].lane_eligible());
        assert!(p.groups[2].is_empty());
        // Sentinels after row 0's real prefix are inert.
        for kp in 10..16 {
            assert_eq!(p.cols[kp], SENTINEL_COL);
            assert_eq!(p.vals[kp], 0.0);
        }
    }

    #[test]
    fn pack_widens_but_never_narrows() {
        let x = batch();
        // Widening to a bigger model dimension is routine (libsvm
        // omits trailing features).
        assert!(PackedRequests::pack(&x, 40).is_ok());
        // A model narrower than the batch is a dimension mismatch.
        let err = PackedRequests::pack(&x, 8).unwrap_err();
        assert!(err.contains("12 features but the model has 8"), "{err}");
    }
}
