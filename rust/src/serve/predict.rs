//! The batched sparse predict kernel.
//!
//! One score per request group: `s_i = Σ_k val[k]·w[col[k]]`, folded
//! in f64 in storage order — the exact recurrence of the old scalar
//! `Csr::row_dot` loop, which makes the portable path **bit-identical**
//! to the pre-serve `Fitted::predict` (pinned in `tests/serve.rs`).
//! Lane-eligible groups run `LANES`-wide chunks through
//! [`SimdBackend::predict_fold_chunk`] (hardware gathers on AVX2);
//! short groups take the scalar fold, exactly like the sweep kernels.
//! Paired backends (`Avx512`) additionally drain full 16-entry pairs
//! through [`SimdBackend::predict_fold_chunk2`] — one 512-bit gather
//! per pair — before the 8-wide loop takes the remainder. Because the
//! fold itself is f64 storage-order on every backend (see the
//! backend-op docs), all backends' scores are bit-identical — the
//! differential suite still asserts the weaker ≤1e-6 contract so a
//! future vectorized fold has room to trade exactness for speed.
//!
//! Backend selection follows the engine rule: callers resolve a
//! [`SimdLevel`] once (per server instance / per `Trainer` facade
//! call) via `simd::resolve` and pass it down — this module performs
//! no feature detection (ci.sh greps it, like the engines).

use super::batch::PackedRequests;
use crate::losses::kernel::LANES2;
use crate::partition::omega::LANES;
use crate::simd::{Portable, SimdBackend, SimdLevel};

/// Score every request in the batch against `w`, appending one f64
/// score per request (in request order) to `out` after clearing it.
///
/// # Panics
/// If `w.len() != reqs.d` (the packer validated every column id
/// against `reqs.d`) or the packed storage is inconsistent — both are
/// caller bugs, not data errors: the server validates requests at
/// parse/pack time and replies `ServeError` there.
pub fn predict_batch(reqs: &PackedRequests, w: &[f32], level: SimdLevel, out: &mut Vec<f64>) {
    match level {
        SimdLevel::Portable => predict_batch_with::<Portable>(reqs, w, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: an Avx2 level is only ever produced by
        // `simd::resolve` (which verified avx2+fma on this CPU) or by
        // tests performing the same guard.
        SimdLevel::Avx2 => unsafe { predict_batch_avx2(reqs, w, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: an Avx512 level is only ever produced by
        // `simd::resolve` (which verified avx512f+avx2+fma) or by tests
        // performing the same guard.
        SimdLevel::Avx512 => unsafe { predict_batch_avx512(reqs, w, out) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdLevel::Avx2 | SimdLevel::Avx512 => {
            unreachable!("simd::resolve never yields x86 backends off x86_64")
        }
    }
}

/// Cheap per-batch bounds validation — the serving analogue of the
/// sweeps' `check_packed_bounds`: after it passes, the chunk loop's
/// unchecked gathers are sound. O(padded_nnz) over the column table
/// only (predict itself is O(padded_nnz) with two more streams, so
/// the scan is a small constant factor, and it is what lets the hot
/// fold drop per-entry bounds checks).
fn check_request_bounds(reqs: &PackedRequests, w: &[f32]) {
    assert_eq!(
        w.len(),
        reqs.d,
        "predict: model has {} weights but the batch was packed against d = {}",
        w.len(),
        reqs.d
    );
    assert_eq!(reqs.cols.len(), reqs.vals.len(), "packed request storage torn");
    let n = w.len() as u32;
    // Sentinels included: the full-width chunk gathers read them.
    assert!(
        reqs.cols.iter().all(|&c| c < n.max(1)) && reqs.d <= i32::MAX as usize,
        "packed request column out of model range"
    );
    for g in &reqs.groups {
        assert!(
            g.pad_start as usize + g.padded_len() <= reqs.cols.len(),
            "request group region out of storage range"
        );
    }
    debug_assert!(crate::simd::is_aligned(&reqs.cols[..]));
    debug_assert!(crate::simd::is_aligned(&reqs.vals[..]));
}

/// [`predict_batch`] monomorphized over an explicit [`SimdBackend`] —
/// the differential-test entry point, exactly like `sweep_lanes_with`.
pub fn predict_batch_with<B: SimdBackend>(reqs: &PackedRequests, w: &[f32], out: &mut Vec<f64>) {
    check_request_bounds(reqs, w);
    out.clear();
    out.reserve(reqs.groups.len());
    let cols = &reqs.cols[..];
    let vals = &reqs.vals[..];
    for g in &reqs.groups {
        let len = g.len();
        let mut s = 0.0f64;
        if len < LANES {
            // Short request: the scalar fold (identical numerics —
            // full-width lanes would waste ≥ half their slots).
            let b = g.pad_start as usize;
            for k in b..b + len {
                s += vals[k] as f64 * w[cols[k] as usize] as f64;
            }
        } else {
            let mut base = g.pad_start as usize;
            let mut rem = len;
            if B::PAIRED {
                // Full 16-entry pairs: no sentinel can appear before
                // the last `len % LANES` padding slots, so `rem >=
                // LANES2` guarantees 16 real entries — the no-`n` pair
                // fold is exact. The fold is the same serial f64
                // storage-order recurrence, so scores stay bitwise.
                while rem >= LANES2 {
                    // SAFETY: `base + LANES2 <= pad_start +
                    // padded_len` (checked above) and every stored
                    // column is < w.len() per `check_request_bounds`.
                    unsafe { B::predict_fold_chunk2(cols, vals, base, w, &mut s) };
                    base += LANES2;
                    rem -= LANES2;
                }
            }
            while rem > 0 {
                let n = rem.min(LANES);
                // SAFETY: `base + LANES` stays within the group's
                // physical lane region (lane-eligible groups are
                // padded to LANES multiples) and every stored column —
                // sentinels included — is < w.len(); both validated by
                // `check_request_bounds` above. n <= LANES.
                unsafe { B::predict_fold_chunk(cols, vals, base, n, w, &mut s) };
                base += LANES;
                rem -= n;
            }
        }
        out.push(s);
    }
}

/// Whole-batch AVX2 compilation unit — the same sweep-granularity
/// `#[target_feature]` boundary the training kernels use
/// (`sweep_lanes_avx2`): the chunk fold and the backend's intrinsic
/// wrappers all inline into one avx2+fma function instead of paying an
/// opaque call per chunk.
///
/// # Safety
/// The running CPU must support avx2+fma — guaranteed by
/// `simd::resolve` (server startup / facade) or an explicit
/// `simd::avx2_supported()` guard at the call site.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn predict_batch_avx2(reqs: &PackedRequests, w: &[f32], out: &mut Vec<f64>) {
    predict_batch_with::<crate::simd::Avx2>(reqs, w, out)
}

/// Whole-batch AVX-512 compilation unit — `predict_batch_avx2`'s twin
/// for the paired backend: 512-bit pair gathers and the 256-bit
/// epilogue all inline into one avx512f+avx2+fma function.
///
/// # Safety
/// The running CPU must support avx512f+avx2+fma — guaranteed by
/// `simd::resolve` or an explicit `simd::avx512_supported()` guard at
/// the call site.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
pub unsafe fn predict_batch_avx512(reqs: &PackedRequests, w: &[f32], out: &mut Vec<f64>) {
    predict_batch_with::<crate::simd::Avx512>(reqs, w, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;

    fn batch_and_w() -> (Csr, Vec<f32>) {
        let rows: Vec<Vec<(u32, f32)>> = (0..7)
            .map(|i| {
                (0..(3 * i) % 11)
                    .map(|j| ((j * 2 + i) as u32 % 12, 0.25 * (i + j) as f32 - 1.0))
                    .collect()
            })
            .collect();
        let x = Csr::from_rows(12, rows);
        let w: Vec<f32> = (0..12).map(|j| ((j * 7) % 5) as f32 * 0.3 - 0.6).collect();
        (x, w)
    }

    #[test]
    fn portable_batch_is_bitwise_row_dot() {
        let (x, w) = batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        let mut got = Vec::new();
        predict_batch(&p, &w, SimdLevel::Portable, &mut got);
        assert_eq!(got.len(), x.rows);
        for i in 0..x.rows {
            assert_eq!(got[i].to_bits(), x.row_dot(i, &w).to_bits(), "row {i}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_batch_matches_portable() {
        if !crate::simd::avx2_supported() {
            eprintln!("skipping: avx2+fma not available on this host");
            return;
        }
        let (x, w) = batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        predict_batch(&p, &w, SimdLevel::Portable, &mut a);
        predict_batch(&p, &w, SimdLevel::Avx2, &mut b);
        // The f64 storage-order fold makes the backends bit-identical
        // today; ≤1e-6 per score is the documented contract.
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() <= 1e-6 * a[i].abs().max(1.0), "row {i}");
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "row {i} fold should be bitwise");
        }
    }

    #[test]
    #[should_panic(expected = "model has")]
    fn dimension_mismatch_is_a_caller_bug() {
        let (x, w) = batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        predict_batch(&p, &w[..8], SimdLevel::Portable, &mut Vec::new());
    }

    /// Rows spanning every pair-loop regime: short (<8), single-chunk,
    /// one pair + ragged tail, two pairs + odd full chunk, empty.
    fn long_batch_and_w() -> (Csr, Vec<f32>) {
        let d = 64u32;
        let rows: Vec<Vec<(u32, f32)>> = [0usize, 3, 8, 15, 16, 20, 24, 33, 40]
            .iter()
            .map(|&len| {
                (0..len)
                    .map(|j| (((j * 7 + len) as u32) % d, 0.125 * (j as f32) - 0.7))
                    .collect()
            })
            .collect();
        let x = Csr::from_rows(d as usize, rows);
        let w: Vec<f32> = (0..d).map(|j| ((j * 13) % 9) as f32 * 0.21 - 0.8).collect();
        (x, w)
    }

    /// `Portable` with the pair loop switched on: every op forwards to
    /// `Portable`, so any score difference vs plain `Portable` can only
    /// come from the pair-loop *logic* (boundaries, epilogue handoff) —
    /// pinned bitwise on every architecture, no AVX-512 host needed.
    #[derive(Clone, Copy)]
    struct PairedFold;
    // SAFETY: pure delegation to `Portable`, which is sound everywhere.
    unsafe impl SimdBackend for PairedFold {
        const NAME: &'static str = "paired-fold";
        const PAIRED: bool = true;
        unsafe fn gather_chunk(
            cols: &[u32],
            vals: &[f32],
            base: usize,
            w: &[f32],
            inv: &[f32],
        ) -> ([usize; LANES], crate::losses::kernel::Lane, crate::losses::kernel::Lane, crate::losses::kernel::Lane)
        {
            // SAFETY: forwarded caller contract.
            unsafe { Portable::gather_chunk(cols, vals, base, w, inv) }
        }
        unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> crate::losses::kernel::Lane {
            // SAFETY: forwarded caller contract.
            unsafe { Portable::gather_idx(src, lj) }
        }
        fn w_grad(
            lam: f32,
            rv: &crate::losses::kernel::Lane,
            iv: &crate::losses::kernel::Lane,
            av: &crate::losses::kernel::Lane,
            xv: &crate::losses::kernel::Lane,
        ) -> crate::losses::kernel::Lane {
            Portable::w_grad(lam, rv, iv, av, xv)
        }
        fn w_step_clamp(
            wv: &crate::losses::kernel::Lane,
            etav: &crate::losses::kernel::Lane,
            gw: &crate::losses::kernel::Lane,
            b: f32,
        ) -> crate::losses::kernel::Lane {
            Portable::w_step_clamp(wv, etav, gw, b)
        }
        fn affine_coeffs(
            bias: f32,
            wv: &crate::losses::kernel::Lane,
            xv: &crate::losses::kernel::Lane,
        ) -> crate::losses::kernel::Lane {
            Portable::affine_coeffs(bias, wv, xv)
        }
        fn l1_grad_lane(w: &crate::losses::kernel::Lane) -> crate::losses::kernel::Lane {
            Portable::l1_grad_lane(w)
        }
        fn l2_grad_lane(w: &crate::losses::kernel::Lane) -> crate::losses::kernel::Lane {
            Portable::l2_grad_lane(w)
        }
        fn adagrad_eta_lane(
            e0: f32,
            eps: f32,
            acc: &mut crate::losses::kernel::Lane,
            g: &crate::losses::kernel::Lane,
        ) -> crate::losses::kernel::Lane {
            Portable::adagrad_eta_lane(e0, eps, acc, g)
        }
        unsafe fn predict_fold_chunk(
            cols: &[u32],
            vals: &[f32],
            base: usize,
            n: usize,
            w: &[f32],
            acc: &mut f64,
        ) {
            // SAFETY: forwarded caller contract.
            unsafe { Portable::predict_fold_chunk(cols, vals, base, n, w, acc) }
        }
    }

    #[test]
    fn pair_loop_is_bitwise_row_dot_at_every_boundary() {
        let (x, w) = long_batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        let (mut plain, mut paired) = (Vec::new(), Vec::new());
        predict_batch_with::<Portable>(&p, &w, &mut plain);
        predict_batch_with::<PairedFold>(&p, &w, &mut paired);
        assert_eq!(plain.len(), x.rows);
        for i in 0..x.rows {
            assert_eq!(plain[i].to_bits(), x.row_dot(i, &w).to_bits(), "row {i}");
            assert_eq!(plain[i].to_bits(), paired[i].to_bits(), "row {i} pair loop");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_batch_matches_portable() {
        if !crate::simd::avx512_supported() {
            eprintln!("skipping: avx512f+avx2+fma not available on this host");
            return;
        }
        let (x, w) = long_batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        predict_batch(&p, &w, SimdLevel::Portable, &mut a);
        predict_batch(&p, &w, SimdLevel::Avx512, &mut b);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() <= 1e-6 * a[i].abs().max(1.0), "row {i}");
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "row {i} fold should be bitwise");
        }
    }
}
