//! The batched sparse predict kernel.
//!
//! One score per request group: `s_i = Σ_k val[k]·w[col[k]]`, folded
//! in f64 in storage order — the exact recurrence of the old scalar
//! `Csr::row_dot` loop, which makes the portable path **bit-identical**
//! to the pre-serve `Fitted::predict` (pinned in `tests/serve.rs`).
//! Lane-eligible groups run `LANES`-wide chunks through
//! [`SimdBackend::predict_fold_chunk`] (hardware gathers on AVX2);
//! short groups take the scalar fold, exactly like the sweep kernels.
//! Because the fold itself is f64 storage-order on every backend (see
//! the backend-op docs), AVX2 and portable scores are bit-identical —
//! the differential suite still asserts the weaker ≤1e-6 contract so a
//! future vectorized fold has room to trade exactness for speed.
//!
//! Backend selection follows the engine rule: callers resolve a
//! [`SimdLevel`] once (per server instance / per `Trainer` facade
//! call) via `simd::resolve` and pass it down — this module performs
//! no feature detection (ci.sh greps it, like the engines).

use super::batch::PackedRequests;
use crate::partition::omega::LANES;
use crate::simd::{Portable, SimdBackend, SimdLevel};

/// Score every request in the batch against `w`, appending one f64
/// score per request (in request order) to `out` after clearing it.
///
/// # Panics
/// If `w.len() != reqs.d` (the packer validated every column id
/// against `reqs.d`) or the packed storage is inconsistent — both are
/// caller bugs, not data errors: the server validates requests at
/// parse/pack time and replies `ServeError` there.
pub fn predict_batch(reqs: &PackedRequests, w: &[f32], level: SimdLevel, out: &mut Vec<f64>) {
    match level {
        SimdLevel::Portable => predict_batch_with::<Portable>(reqs, w, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: an Avx2 level is only ever produced by
        // `simd::resolve` (which verified avx2+fma on this CPU) or by
        // tests performing the same guard.
        SimdLevel::Avx2 => unsafe { predict_batch_avx2(reqs, w, out) },
        #[cfg(not(target_arch = "x86_64"))]
        SimdLevel::Avx2 => unreachable!("simd::resolve never yields Avx2 off x86_64"),
    }
}

/// Cheap per-batch bounds validation — the serving analogue of the
/// sweeps' `check_packed_bounds`: after it passes, the chunk loop's
/// unchecked gathers are sound. O(padded_nnz) over the column table
/// only (predict itself is O(padded_nnz) with two more streams, so
/// the scan is a small constant factor, and it is what lets the hot
/// fold drop per-entry bounds checks).
fn check_request_bounds(reqs: &PackedRequests, w: &[f32]) {
    assert_eq!(
        w.len(),
        reqs.d,
        "predict: model has {} weights but the batch was packed against d = {}",
        w.len(),
        reqs.d
    );
    assert_eq!(reqs.cols.len(), reqs.vals.len(), "packed request storage torn");
    let n = w.len() as u32;
    // Sentinels included: the full-width chunk gathers read them.
    assert!(
        reqs.cols.iter().all(|&c| c < n.max(1)) && reqs.d <= i32::MAX as usize,
        "packed request column out of model range"
    );
    for g in &reqs.groups {
        assert!(
            g.pad_start as usize + g.padded_len() <= reqs.cols.len(),
            "request group region out of storage range"
        );
    }
    debug_assert!(crate::simd::is_aligned(&reqs.cols[..]));
    debug_assert!(crate::simd::is_aligned(&reqs.vals[..]));
}

/// [`predict_batch`] monomorphized over an explicit [`SimdBackend`] —
/// the differential-test entry point, exactly like `sweep_lanes_with`.
pub fn predict_batch_with<B: SimdBackend>(reqs: &PackedRequests, w: &[f32], out: &mut Vec<f64>) {
    check_request_bounds(reqs, w);
    out.clear();
    out.reserve(reqs.groups.len());
    let cols = &reqs.cols[..];
    let vals = &reqs.vals[..];
    for g in &reqs.groups {
        let len = g.len();
        let mut s = 0.0f64;
        if len < LANES {
            // Short request: the scalar fold (identical numerics —
            // full-width lanes would waste ≥ half their slots).
            let b = g.pad_start as usize;
            for k in b..b + len {
                s += vals[k] as f64 * w[cols[k] as usize] as f64;
            }
        } else {
            let mut base = g.pad_start as usize;
            let mut rem = len;
            while rem > 0 {
                let n = rem.min(LANES);
                // SAFETY: `base + LANES` stays within the group's
                // physical lane region (lane-eligible groups are
                // padded to LANES multiples) and every stored column —
                // sentinels included — is < w.len(); both validated by
                // `check_request_bounds` above. n <= LANES.
                unsafe { B::predict_fold_chunk(cols, vals, base, n, w, &mut s) };
                base += LANES;
                rem -= n;
            }
        }
        out.push(s);
    }
}

/// Whole-batch AVX2 compilation unit — the same sweep-granularity
/// `#[target_feature]` boundary the training kernels use
/// (`sweep_lanes_avx2`): the chunk fold and the backend's intrinsic
/// wrappers all inline into one avx2+fma function instead of paying an
/// opaque call per chunk.
///
/// # Safety
/// The running CPU must support avx2+fma — guaranteed by
/// `simd::resolve` (server startup / facade) or an explicit
/// `simd::avx2_supported()` guard at the call site.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn predict_batch_avx2(reqs: &PackedRequests, w: &[f32], out: &mut Vec<f64>) {
    predict_batch_with::<crate::simd::Avx2>(reqs, w, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;

    fn batch_and_w() -> (Csr, Vec<f32>) {
        let rows: Vec<Vec<(u32, f32)>> = (0..7)
            .map(|i| {
                (0..(3 * i) % 11)
                    .map(|j| ((j * 2 + i) as u32 % 12, 0.25 * (i + j) as f32 - 1.0))
                    .collect()
            })
            .collect();
        let x = Csr::from_rows(12, rows);
        let w: Vec<f32> = (0..12).map(|j| ((j * 7) % 5) as f32 * 0.3 - 0.6).collect();
        (x, w)
    }

    #[test]
    fn portable_batch_is_bitwise_row_dot() {
        let (x, w) = batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        let mut got = Vec::new();
        predict_batch(&p, &w, SimdLevel::Portable, &mut got);
        assert_eq!(got.len(), x.rows);
        for i in 0..x.rows {
            assert_eq!(got[i].to_bits(), x.row_dot(i, &w).to_bits(), "row {i}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_batch_matches_portable() {
        if !crate::simd::avx2_supported() {
            eprintln!("skipping: avx2+fma not available on this host");
            return;
        }
        let (x, w) = batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        predict_batch(&p, &w, SimdLevel::Portable, &mut a);
        predict_batch(&p, &w, SimdLevel::Avx2, &mut b);
        // The f64 storage-order fold makes the backends bit-identical
        // today; ≤1e-6 per score is the documented contract.
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() <= 1e-6 * a[i].abs().max(1.0), "row {i}");
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "row {i} fold should be bitwise");
        }
    }

    #[test]
    #[should_panic(expected = "model has")]
    fn dimension_mismatch_is_a_caller_bug() {
        let (x, w) = batch_and_w();
        let p = PackedRequests::pack(&x, w.len()).unwrap();
        predict_batch(&p, &w[..8], SimdLevel::Portable, &mut Vec::new());
    }
}
