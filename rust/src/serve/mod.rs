//! Serving subsystem (DESIGN.md §Serving): batched SIMD sparse
//! inference, request metrics, and a model server over the framed
//! transport.
//!
//! The paper's premise is that *training* at scale is the bottleneck;
//! the ROADMAP's north star — serving heavy traffic from millions of
//! users — needs the other half. This module is that half:
//!
//! * [`batch::PackedRequests`] — predict requests packed into the same
//!   lane-major, sentinel-padded SoA layout as the training
//!   `PackedBlocks` (§Alignment `AVec` storage, `LANES`-wide chunks,
//!   read-only sentinel slots), so inference reuses the gather
//!   machinery the sweep kernels built.
//! * [`predict`] — the batched dot-product kernel, monomorphized over
//!   `simd::SimdBackend` exactly like the sweeps: the portable backend
//!   is bit-identical to the old scalar `Csr::row_dot` loop (pinned by
//!   test — `Fitted::predict`'s API and values are unchanged), the
//!   AVX2 backend replaces each chunk's 8 scalar indexed loads with a
//!   hardware gather. The backend is resolved **once per server
//!   instance** by `simd::resolve` and recorded in the stats — no
//!   feature detection inside this module (ci.sh greps it, same as the
//!   engines).
//! * [`metrics`] — per-request latency/throughput counters streamed
//!   through an observer, mirroring the training side's
//!   `EpochObserver` layer.
//! * [`server`] — `dso serve`: loads a `Model`, answers
//!   libsvm-formatted [`crate::net::wire::Msg::Predict`] requests over
//!   the existing length-prefixed checksummed framing (`FrameConn`),
//!   supports hot model reload after a warm-start retrain
//!   (`Trainer::fit_from`), and reports its counters on demand.

pub mod batch;
pub mod metrics;
pub mod predict;
pub mod server;

pub use batch::PackedRequests;
pub use metrics::{NullServeObserver, RequestStat, ServeObserver, ServeStats};
pub use predict::{predict_batch, predict_batch_with};
pub use server::{serve, ServeOptions, Server};
