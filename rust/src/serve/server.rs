//! The model server behind `dso serve`.
//!
//! Loads a persisted [`Model`], binds a Unix socket, and answers
//! libsvm-formatted predict requests over the exact framed transport
//! the multi-process trainer speaks (`FrameConn`: length-prefixed,
//! FNV-checksummed, `Msg`-encoded — nothing serving-specific below the
//! message layer). The protocol is four request kinds:
//!
//! * `Predict { id, batch }` — `batch` is libsvm text (labels
//!   mandatory per the format, ignored for scoring). Replies
//!   `Scores { id, scores }` with one f64 margin per request line, or
//!   `ServeError { id, message }` carrying the parser's line-numbered
//!   message / the packer's dimension-mismatch message. A bad batch
//!   never tears down the connection.
//! * `Reload { path }` — hot-swaps the model after e.g. a warm-start
//!   retrain (`Trainer::fit_from`). Replies `Ack { seq: reload# }` on
//!   success; on failure replies `ServeError` and **keeps serving the
//!   old model**.
//! * `StatsReq` — replies `StatsReply` with the cumulative counters
//!   ([`ServeStats`]), including which SIMD backend this instance
//!   resolved at startup.
//! * `Shutdown` — replies `Bye` and stops the server.
//!
//! Corrupt frames are counted and answered with `ServeError` (the
//! serving analogue of the trainer's `Nack`); unknown training-side
//! messages are ignored. Connections are served one at a time in
//! accept order — the benchmark target is kernel throughput on one
//! socket, not connection fan-out.
//!
//! The SIMD backend is resolved **once**, at [`Server::bind`], via
//! `simd::resolve` — the same single feature-detection site the
//! engines use — then recorded in the stats and stamped on every
//! [`RequestStat`]. Under `--simd auto` that resolution is the
//! measured micro-autotune (`simd::autotune`): every host-supported
//! backend is timed for a few milliseconds on the synthetic probe
//! workload and the observed winner serves; the full report (winner +
//! per-backend throughputs) is kept on the instance for the CLI to
//! log. This module contains no feature detection and no bare
//! `unwrap`/`expect` on the socket paths (both gated by ci.sh).

use super::batch::PackedRequests;
use super::metrics::{RequestStat, ServeObserver, ServeStats};
use super::predict::predict_batch;
use crate::api::Model;
use crate::config::SimdKind;
use crate::net::transport::{ConnIn, FrameConn};
use crate::net::wire::Msg;
use crate::simd::{self, SimdLevel};
use anyhow::{Context, Result};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// How a [`Server`] is stood up.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Model file to serve ([`Model::load`] format).
    pub model_path: PathBuf,
    /// Unix socket to listen on (a stale file there is replaced).
    pub socket_path: PathBuf,
    /// SIMD backend policy: `Auto` measures every supported backend
    /// and serves on the winner; `Portable`/`Avx2`/`Avx512` force —
    /// identical semantics to training's `cluster.simd`, including the
    /// no-silent-fallback refusal of an unsupported forced level.
    pub simd: SimdKind,
    /// Per-read timeout on an open connection; bounds how long a
    /// silent client can hold the (serial) accept loop.
    pub recv_timeout: Duration,
}

impl ServeOptions {
    pub fn new(model_path: impl Into<PathBuf>, socket_path: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions {
            model_path: model_path.into(),
            socket_path: socket_path.into(),
            simd: SimdKind::Auto,
            recv_timeout: Duration::from_millis(500),
        }
    }
}

/// A bound, model-loaded server ready to [`run`](Server::run).
pub struct Server {
    model: Model,
    level: SimdLevel,
    /// The measured selection report when the instance was bound with
    /// `SimdKind::Auto`; `None` under a forced level (forcing obeys,
    /// it never measures).
    autotune: Option<&'static crate::simd::autotune::AutotuneReport>,
    stats: ServeStats,
    listener: UnixListener,
    socket_path: PathBuf,
    recv_timeout: Duration,
    /// Reused score buffer — one allocation per server, not per batch.
    scores: Vec<f64>,
}

impl Server {
    /// Load the model, resolve the SIMD backend (once — recorded for
    /// the lifetime of the instance; `Auto` = measured autotune), and
    /// bind the socket.
    pub fn bind(opts: &ServeOptions) -> Result<Server> {
        let model = Model::load(&opts.model_path)
            .with_context(|| format!("loading model {}", opts.model_path.display()))?;
        let (level, autotune) = if opts.simd == SimdKind::Auto {
            let report = crate::simd::autotune::auto_report();
            (report.chosen, Some(report))
        } else {
            (simd::resolve(opts.simd), None)
        };
        if opts.socket_path.exists() {
            std::fs::remove_file(&opts.socket_path)
                .with_context(|| format!("removing stale socket {}", opts.socket_path.display()))?;
        }
        let listener = UnixListener::bind(&opts.socket_path)
            .with_context(|| format!("binding {}", opts.socket_path.display()))?;
        Ok(Server {
            model,
            level,
            autotune,
            stats: ServeStats::new(level.name()),
            listener,
            socket_path: opts.socket_path.clone(),
            recv_timeout: opts.recv_timeout,
            scores: Vec::new(),
        })
    }

    /// The socket clients should dial.
    pub fn socket_path(&self) -> &Path {
        &self.socket_path
    }

    /// The backend every batch on this instance runs on.
    pub fn backend(&self) -> &'static str {
        self.stats.backend
    }

    /// The measured selection report, when this instance was bound
    /// with `--simd auto` (`None` under a forced level). `chosen`
    /// always equals [`Server::backend`]'s level; the per-backend
    /// throughputs are what the CLI logs at startup.
    pub fn autotune_report(&self) -> Option<&crate::simd::autotune::AutotuneReport> {
        self.autotune
    }

    /// Feature dimension of the currently served model.
    pub fn model_dim(&self) -> usize {
        self.model.w.len()
    }

    /// Cumulative counters so far.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Accept and serve connections until a client sends `Shutdown`.
    /// Per-connection I/O errors (e.g. a client resetting mid-frame)
    /// end that connection, not the server.
    pub fn run(&mut self, obs: &mut dyn ServeObserver) -> Result<()> {
        loop {
            let (stream, _) = self.listener.accept().context("accepting serve connection")?;
            match self.handle_conn(stream, obs) {
                Ok(true) => return Ok(()),
                Ok(false) => {}
                // A torn connection is the client's problem; keep
                // accepting.
                Err(_) => self.stats.record_error(),
            }
        }
    }

    /// Serve one connection to EOF. Returns `Ok(true)` iff the client
    /// requested shutdown.
    fn handle_conn(&mut self, stream: UnixStream, obs: &mut dyn ServeObserver) -> Result<bool> {
        let mut conn = FrameConn::new(stream);
        conn.set_recv_timeout(Some(self.recv_timeout))
            .context("setting serve read timeout")?;
        loop {
            match conn.recv().context("receiving serve frame")? {
                ConnIn::Msg(Msg::Predict { id, batch }) => {
                    self.answer_predict(&mut conn, obs, id, &batch)?;
                }
                ConnIn::Msg(Msg::Reload { path }) => {
                    match Model::load(Path::new(&path)) {
                        Ok(m) => {
                            self.model = m;
                            self.stats.record_reload();
                            obs.on_reload(&path, self.model.w.len());
                            conn.send(&Msg::Ack { seq: self.stats.reloads })
                                .context("acking reload")?;
                        }
                        Err(e) => {
                            // The old model keeps serving.
                            self.stats.record_error();
                            conn.send(&Msg::ServeError { id: 0, message: format!("reload: {e:#}") })
                                .context("refusing reload")?;
                        }
                    }
                }
                ConnIn::Msg(Msg::StatsReq) => {
                    let reply = self.stats.to_reply(self.model.w.len());
                    conn.send(&reply).context("sending stats")?;
                }
                ConnIn::Msg(Msg::Shutdown) => {
                    conn.send(&Msg::Bye).context("sending bye")?;
                    return Ok(true);
                }
                // Training-side traffic on a serving socket: tolerated
                // and ignored, like the trainer ignores late acks.
                ConnIn::Msg(_) => {}
                ConnIn::Corrupt => {
                    self.stats.record_error();
                    conn.send(&Msg::ServeError { id: 0, message: "corrupt frame".into() })
                        .context("reporting corrupt frame")?;
                }
                ConnIn::TimedOut => {}
                ConnIn::Eof => return Ok(false),
            }
        }
    }

    /// Parse → pack → score one predict batch, replying `Scores` or a
    /// `ServeError` that names the offending line / dimension.
    fn answer_predict(
        &mut self,
        conn: &mut FrameConn,
        obs: &mut dyn ServeObserver,
        id: u64,
        batch: &str,
    ) -> Result<()> {
        let start = Instant::now();
        let ds = match crate::data::libsvm::parse("request", batch, 0) {
            Ok(ds) => ds,
            Err(e) => {
                self.stats.record_error();
                conn.send(&Msg::ServeError { id, message: e.to_string() })
                    .context("refusing unparseable batch")?;
                return Ok(());
            }
        };
        let packed = match PackedRequests::pack(&ds.x, self.model.w.len()) {
            Ok(p) => p,
            Err(message) => {
                self.stats.record_error();
                conn.send(&Msg::ServeError { id, message })
                    .context("refusing mismatched batch")?;
                return Ok(());
            }
        };
        predict_batch(&packed, &self.model.w, self.level, &mut self.scores);
        conn.send(&Msg::Scores { id, scores: self.scores.clone() })
            .context("sending scores")?;
        let stat = RequestStat {
            id,
            rows: packed.n_requests(),
            nnz: packed.nnz(),
            latency_s: start.elapsed().as_secs_f64(),
            backend: self.stats.backend,
        };
        self.stats.record(&stat);
        obs.on_request(&stat);
        Ok(())
    }
}

/// Convenience: bind and run in one call (what `dso serve` does).
pub fn serve(opts: &ServeOptions, obs: &mut dyn ServeObserver) -> Result<()> {
    Server::bind(opts)?.run(obs)
}
