//! Per-request serving metrics, streamed through an observer layer
//! that mirrors the training side's `EpochObserver`.
//!
//! The server records one [`RequestStat`] per answered predict batch
//! and folds it into its cumulative [`ServeStats`]; an optional
//! [`ServeObserver`] sees each stat the moment it is recorded (the
//! `dso serve` CLI wires a stderr logger through here, tests wire
//! closures). Counters are also exported over the wire on demand as
//! `Msg::StatsReply`.

use crate::net::wire::Msg;

/// One answered predict request, as seen by the observer.
#[derive(Clone, Debug)]
pub struct RequestStat {
    /// Caller-chosen request id, echoed from `Msg::Predict`.
    pub id: u64,
    /// Rows (individual examples) scored in the batch.
    pub rows: usize,
    /// Real non-zeros scored (sentinel padding excluded).
    pub nnz: usize,
    /// Wall-clock seconds from frame decode to scores encoded.
    pub latency_s: f64,
    /// SIMD backend the scores ran on ("portable" / "avx2" /
    /// "avx512") — resolved once per server instance (measured, under
    /// `--simd auto`), recorded per request so a mixed-fleet log stays
    /// attributable.
    pub backend: &'static str,
}

/// Live callback for serving events. Implemented for any
/// `FnMut(&RequestStat)` closure, exactly like `EpochObserver` is for
/// `FnMut(&EvalRow)`.
pub trait ServeObserver {
    fn on_request(&mut self, stat: &RequestStat);

    /// Called after a successful hot model reload. Default: ignore, so
    /// closures stay observers.
    fn on_reload(&mut self, _path: &str, _d: usize) {}
}

impl<F: FnMut(&RequestStat)> ServeObserver for F {
    fn on_request(&mut self, stat: &RequestStat) {
        self(stat)
    }
}

/// Observer that drops everything (headless servers).
pub struct NullServeObserver;

impl ServeObserver for NullServeObserver {
    fn on_request(&mut self, _stat: &RequestStat) {}
}

/// Cumulative serving counters for one server instance.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Predict batches answered with scores.
    pub served: u64,
    /// Total rows scored across all batches.
    pub rows: u64,
    /// Requests refused with `Msg::ServeError` (parse failures,
    /// dimension mismatches, failed reloads).
    pub errors: u64,
    /// Successful hot model reloads.
    pub reloads: u64,
    /// Sum of per-request latencies, seconds.
    pub total_latency_s: f64,
    /// Worst single-request latency, seconds.
    pub max_latency_s: f64,
    /// Backend every batch ran on ("portable" / "avx2" / "avx512").
    pub backend: &'static str,
}

impl ServeStats {
    pub fn new(backend: &'static str) -> ServeStats {
        ServeStats { backend, ..ServeStats::default() }
    }

    /// Fold one answered request into the counters.
    pub fn record(&mut self, stat: &RequestStat) {
        self.served += 1;
        self.rows += stat.rows as u64;
        self.total_latency_s += stat.latency_s;
        if stat.latency_s > self.max_latency_s {
            self.max_latency_s = stat.latency_s;
        }
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn record_reload(&mut self) {
        self.reloads += 1;
    }

    /// Mean per-request latency in seconds (0 before any request).
    pub fn mean_latency_s(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_latency_s / self.served as f64
        }
    }

    /// Rows scored per second of cumulative serving latency (the
    /// kernel-side throughput; 0 before any request).
    pub fn rows_per_sec(&self) -> f64 {
        if self.total_latency_s <= 0.0 {
            0.0
        } else {
            self.rows as f64 / self.total_latency_s
        }
    }

    /// Export as the wire reply (latencies in integer microseconds —
    /// saturating, not wrapping, on absurd values).
    pub fn to_reply(&self, d: usize) -> Msg {
        let us = |s: f64| (s * 1e6).clamp(0.0, u64::MAX as f64) as u64;
        Msg::StatsReply {
            served: self.served,
            rows: self.rows,
            errors: self.errors,
            reloads: self.reloads,
            total_latency_us: us(self.total_latency_s),
            max_latency_us: us(self.max_latency_s),
            backend: self.backend.to_string(),
            d: d as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_fold_requests_and_export() {
        let mut st = ServeStats::new("portable");
        let mut seen = 0usize;
        {
            let mut obs = |stat: &RequestStat| seen += stat.rows;
            for (rows, lat) in [(4usize, 0.002f64), (1, 0.010), (7, 0.001)] {
                let stat = RequestStat {
                    id: 9,
                    rows,
                    nnz: rows * 3,
                    latency_s: lat,
                    backend: "portable",
                };
                ServeObserver::on_request(&mut obs, &stat);
                st.record(&stat);
            }
        }
        st.record_error();
        st.record_reload();
        assert_eq!(seen, 12);
        assert_eq!((st.served, st.rows, st.errors, st.reloads), (3, 12, 1, 1));
        assert!((st.max_latency_s - 0.010).abs() < 1e-12);
        assert!((st.mean_latency_s() - 0.013 / 3.0).abs() < 1e-12);
        assert!(st.rows_per_sec() > 0.0);
        match st.to_reply(42) {
            Msg::StatsReply { served, rows, max_latency_us, backend, d, .. } => {
                assert_eq!((served, rows, d), (3, 12, 42));
                assert_eq!(max_latency_us, 10_000);
                assert_eq!(backend, "portable");
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
}
