//! Reliable framed connections over Unix-domain sockets.
//!
//! A [`FrameConn`] wraps one duplex `UnixStream` with the framing from
//! [`super::wire`] and adds the reliability mechanics the multi-process
//! ring needs:
//!
//! * **sequenced retransmission** — sequenced frames (`Deliver`,
//!   `Adopt`, `Fwd`) are retained verbatim until the peer acknowledges
//!   them, so a `Nack` (corrupt frame) or a reconnect replays exactly
//!   the bytes the peer missed. Replaying *verbatim* matters: delta
//!   baselines stay consistent because the peer applies each sequence
//!   number exactly once, in order;
//! * **corrupt-frame rejection** — a frame whose checksum fails is
//!   surfaced as [`ConnIn::Corrupt`] (never delivered), and the caller
//!   answers with `Nack` to trigger the resend;
//! * **bounded-wait receive** — the socket read timeout makes `recv`
//!   return [`ConnIn::TimedOut`] at frame boundaries, which is what
//!   drives worker heartbeats and the supervisor's death detection;
//! * **dial with backoff** — [`connect_with_backoff`] reuses the ring's
//!   [`Backoff`] policy for the initial dial and for reconnects after
//!   a link fault.
//!
//! Everything here is `Result`-routed: socket I/O must never
//! `unwrap()`/`expect()` (scripts/ci.sh greps this file), because a
//! peer dying mid-frame is an expected event the supervisor turns
//! into ring degradation, not a coordinator panic.

use super::router::Backoff;
use super::wire::{self, FrameIn, Msg};
use std::collections::VecDeque;
use std::io;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::{Duration, Instant};

/// One decoded receive step.
#[derive(Debug)]
pub enum ConnIn {
    Msg(Msg),
    /// No frame began within the socket's read timeout.
    TimedOut,
    /// Peer closed the socket (or died mid-frame).
    Eof,
    /// A frame arrived but failed its checksum (or decoded to no known
    /// message); the caller should `Nack` the next expected sequence.
    Corrupt,
}

/// A framed, reliable-with-retransmission connection.
pub struct FrameConn {
    stream: UnixStream,
    /// Sequenced frames not yet acknowledged, retained as encoded
    /// payload bytes for verbatim replay: (seq, payload).
    unacked: VecDeque<(u64, Vec<u8>)>,
    pub sent_bytes: u64,
    pub recv_bytes: u64,
    pub sent_frames: u64,
    pub resent_frames: u64,
    pub corrupt_frames: u64,
}

impl FrameConn {
    pub fn new(stream: UnixStream) -> FrameConn {
        FrameConn {
            stream,
            unacked: VecDeque::new(),
            sent_bytes: 0,
            recv_bytes: 0,
            sent_frames: 0,
            resent_frames: 0,
            corrupt_frames: 0,
        }
    }

    /// Bound how long [`recv`](Self::recv) waits for a frame to begin.
    pub fn set_recv_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(d)
    }

    /// Clone the raw stream (e.g. to shut it down from another thread).
    pub fn try_clone_stream(&self) -> io::Result<UnixStream> {
        self.stream.try_clone()
    }

    /// Swap in a fresh stream after a reconnect. Unacked frames are
    /// retained; call [`resend_all`](Self::resend_all) after the new
    /// connection has re-identified itself.
    pub fn replace_stream(&mut self, stream: UnixStream) {
        self.stream = stream;
    }

    /// Send an unsequenced message (handshake, heartbeat, acks).
    pub fn send(&mut self, msg: &Msg) -> io::Result<()> {
        let payload = msg.encode();
        let n = wire::write_frame(&mut self.stream, &payload)?;
        self.sent_bytes += n as u64;
        self.sent_frames += 1;
        Ok(())
    }

    /// Send a sequenced message and retain it for retransmission until
    /// [`ack`](Self::ack)ed. On a write error the frame *stays* queued,
    /// so a reconnect + `resend_all` delivers it.
    pub fn send_tracked(&mut self, seq: u64, msg: &Msg) -> io::Result<()> {
        let payload = msg.encode();
        self.unacked.push_back((seq, payload));
        let back = match self.unacked.back() {
            Some((_, p)) => p,
            None => return Ok(()), // unreachable: just pushed
        };
        let n = wire::write_frame(&mut self.stream, back)?;
        self.sent_bytes += n as u64;
        self.sent_frames += 1;
        Ok(())
    }

    /// Drop every retained frame with sequence <= `seq` (cumulative
    /// acknowledgement).
    pub fn ack(&mut self, seq: u64) {
        while self.unacked.front().is_some_and(|&(s, _)| s <= seq) {
            self.unacked.pop_front();
        }
    }

    /// Retransmit every retained frame with sequence >= `seq`, in
    /// order. Returns how many frames went out.
    pub fn resend_from(&mut self, seq: u64) -> io::Result<usize> {
        let mut n = 0usize;
        for i in 0..self.unacked.len() {
            if self.unacked[i].0 >= seq {
                let bytes = wire::write_frame(&mut self.stream, &self.unacked[i].1)?;
                self.sent_bytes += bytes as u64;
                self.resent_frames += 1;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Retransmit everything unacked (reconnect recovery).
    pub fn resend_all(&mut self) -> io::Result<usize> {
        self.resend_from(0)
    }

    /// How many frames are awaiting acknowledgement.
    pub fn unacked_len(&self) -> usize {
        self.unacked.len()
    }

    /// Receive one message (bounded by the socket read timeout, if
    /// set). Corruption and EOF are data, not errors — only genuine
    /// I/O failures return `Err`.
    pub fn recv(&mut self) -> io::Result<ConnIn> {
        match wire::read_frame(&mut self.stream)? {
            FrameIn::Eof => Ok(ConnIn::Eof),
            FrameIn::TimedOut => Ok(ConnIn::TimedOut),
            FrameIn::Corrupt { wire_bytes } => {
                self.corrupt_frames += 1;
                self.recv_bytes += wire_bytes as u64;
                Ok(ConnIn::Corrupt)
            }
            FrameIn::Frame(payload) => {
                self.recv_bytes += (wire::FRAME_HEADER + payload.len()) as u64;
                match Msg::decode(&payload) {
                    Ok(m) => Ok(ConnIn::Msg(m)),
                    Err(_) => {
                        self.corrupt_frames += 1;
                        Ok(ConnIn::Corrupt)
                    }
                }
            }
        }
    }
}

/// Dial `path`, retrying with exponential [`Backoff`] until `deadline`
/// elapses. Used both for the initial worker dial (the listener may
/// not be accepting yet) and for reconnects after a link fault.
pub fn connect_with_backoff(path: &Path, deadline: Duration) -> io::Result<UnixStream> {
    let start = Instant::now();
    let mut backoff = Backoff::new(1, 250);
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if start.elapsed() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(backoff.next());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn pair() -> (FrameConn, FrameConn) {
        let (a, b) = UnixStream::pair().expect("socketpair");
        (FrameConn::new(a), FrameConn::new(b))
    }

    #[test]
    fn send_recv_round_trips_over_a_socketpair() {
        let (mut a, mut b) = pair();
        a.send(&Msg::Hello { worker: 5 }).unwrap();
        match b.recv().unwrap() {
            ConnIn::Msg(Msg::Hello { worker }) => assert_eq!(worker, 5),
            other => panic!("got {other:?}"),
        }
        assert!(a.sent_bytes > 0);
        assert_eq!(b.recv_bytes, a.sent_bytes);
    }

    #[test]
    fn recv_times_out_at_frame_boundaries() {
        let (_a, mut b) = pair();
        b.set_recv_timeout(Some(Duration::from_millis(30))).unwrap();
        let t0 = Instant::now();
        assert!(matches!(b.recv().unwrap(), ConnIn::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn peer_drop_is_eof() {
        let (a, mut b) = pair();
        drop(a);
        assert!(matches!(b.recv().unwrap(), ConnIn::Eof));
    }

    #[test]
    fn corrupt_frame_rejected_then_repaired_by_nack_resend() {
        let (mut a, mut b) = pair();
        let msg = Msg::Deliver {
            seq: 0,
            block_id: 1,
            hops: 0,
            w: vec![1.0, 2.0, 3.0],
            acc: vec![0.0; 3],
        };
        // A corrupted copy reaches the receiver first: same payload,
        // one flipped bit (as if the link damaged the frame in
        // transit), then the sender's tracked original.
        let mut frame = Vec::new();
        wire::write_frame(&mut frame, &msg.encode()).unwrap();
        frame[wire::FRAME_HEADER + 2] ^= 0x01;
        a.try_clone_stream().unwrap().write_all(&frame).unwrap();
        assert!(matches!(b.recv().unwrap(), ConnIn::Corrupt), "bad frame must not deliver");
        assert_eq!(b.corrupt_frames, 1);

        // Receiver nacks; sender retransmits the retained frame.
        a.send_tracked(0, &msg).unwrap(); // the "lost" original, still queued
        match b.recv().unwrap() {
            ConnIn::Msg(m) => assert_eq!(m.encode(), msg.encode()),
            other => panic!("got {other:?}"),
        }
        b.send(&Msg::Nack { seq: 0 }).unwrap();
        match a.recv().unwrap() {
            ConnIn::Msg(Msg::Nack { seq }) => {
                assert_eq!(a.resend_from(seq).unwrap(), 1);
            }
            other => panic!("got {other:?}"),
        }
        match b.recv().unwrap() {
            ConnIn::Msg(m) => assert_eq!(m.encode(), msg.encode(), "resend differs"),
            other => panic!("got {other:?}"),
        }
        assert_eq!(a.resent_frames, 1);
    }

    #[test]
    fn ack_prunes_cumulatively_and_resend_respects_the_floor() {
        let (mut a, _b) = pair();
        for seq in 0..4u64 {
            a.send_tracked(seq, &Msg::Ack { seq }).unwrap();
        }
        assert_eq!(a.unacked_len(), 4);
        a.ack(1);
        assert_eq!(a.unacked_len(), 2, "cumulative ack drops 0 and 1");
        assert_eq!(a.resend_from(3).unwrap(), 1, "only seq 3 is >= the floor");
        a.ack(10);
        assert_eq!(a.unacked_len(), 0);
        assert_eq!(a.resend_all().unwrap(), 0);
    }

    #[test]
    fn connect_with_backoff_gives_up_after_deadline() {
        let path = std::env::temp_dir().join("dso-no-such-listener.sock");
        let t0 = Instant::now();
        let r = connect_with_backoff(&path, Duration::from_millis(60));
        assert!(r.is_err());
        assert!(t0.elapsed() >= Duration::from_millis(60));
    }
}
