//! Deterministic fault injection for the simulated cluster.
//!
//! A [`FaultPlan`] is a *schedule*, not a dice roll: every fault is
//! pinned to a `(worker, epoch, iter)` coordinate before the run
//! starts, so a chaos run can be replayed exactly (the determinism
//! contract in DESIGN.md §Fault-tolerance). The clock is engine-local:
//!
//! * **sync engine** — `epoch` is the 0-based training epoch and
//!   `iter` the inner ring iteration `r ∈ [0, p)`;
//! * **async engine** — each worker counts its own block visits `v`
//!   and maps them to `epoch = v / p`, `iter = v % p` (p visits ≈ one
//!   worker-epoch of work).
//!
//! Fault kinds, split by what they act on:
//!
//! * compute faults ([`WorkerFault`]): `Stall` (the worker sleeps
//!   before the visit — a straggler), `Die` (the worker panics — or,
//!   in process mode, exits gracefully — at the visit; the async
//!   engines recover, see `async_engine` / `net::supervisor`), `Kill`
//!   (process mode: the worker is SIGKILLed at the visit — no
//!   goodbye, no cleanup; death is detected over the socket), and
//!   `Partition` (process mode: the worker's link drops for a bounded
//!   interval, exercising reconnect + unacked-frame resend);
//! * message faults ([`MsgFault`]): `Delay` (the outgoing token is
//!   held back) and `Drop` (the transport "loses" the message — the
//!   async engine reroutes the token instead of losing the block).
//!
//! `Kill` and `Partition` only make sense where workers are real
//! processes on real sockets, so `TrainConfig::validate` rejects them
//! outside `--mode dso-proc`; the thread engine maps them to the
//! nearest in-process equivalent (`Die` / `Stall`) if reached via the
//! deprecated shims.
//!
//! Plans come from three places, all reduced to the same schedule:
//! the builder methods (tests), the `spec` grammar (config/CLI:
//! `cluster.faults` / `--faults`), and [`FaultPlan::sampled`] (seeded
//! rates expanded *up front* into pinned events — sampling happens
//! once, at plan construction, never during the run).

use crate::util::rng::Xoshiro256;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A compute fault: acts on the worker before it sweeps a block visit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerFault {
    /// Sleep this long before the visit (straggler injection).
    Stall { millis: u64 },
    /// Panic at the visit (worker death). In process mode this is the
    /// *graceful* death: the worker says goodbye and exits cleanly.
    Die,
    /// Process mode only: the worker is SIGKILLed at the visit — hard
    /// death, detected via the socket rather than announced.
    Kill,
    /// Process mode only: the worker's link goes down for this long;
    /// the worker drops its connection, then redials with backoff and
    /// resends unacked frames.
    Partition { millis: u64 },
}

/// A message fault: acts on the token the worker sends after a visit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgFault {
    /// The transport loses the message.
    Drop,
    /// The message is held back this long before sending.
    Delay { millis: u64 },
}

type Key = (usize, usize, usize); // (worker, epoch, iter), all 0-based

/// A deterministic schedule of injected faults (see module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    compute: BTreeMap<Key, WorkerFault>,
    message: BTreeMap<Key, MsgFault>,
}

/// Per-(worker, visit) fault rates for [`FaultPlan::sampled`].
#[derive(Clone, Copy, Debug)]
pub struct FaultRates {
    pub stall: f64,
    pub stall_ms: u64,
    pub die: f64,
    pub drop: f64,
    pub delay: f64,
    pub delay_ms: u64,
}

impl Default for FaultRates {
    fn default() -> Self {
        FaultRates { stall: 0.0, stall_ms: 10, die: 0.0, drop: 0.0, delay: 0.0, delay_ms: 5 }
    }
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.compute.is_empty() && self.message.is_empty()
    }

    pub fn len(&self) -> usize {
        self.compute.len() + self.message.len()
    }

    /// Whether any worker is scheduled to die (gracefully or by
    /// SIGKILL).
    pub fn has_deaths(&self) -> bool {
        self.compute.values().any(|f| matches!(f, WorkerFault::Die | WorkerFault::Kill))
    }

    /// Whether any message is scheduled to be dropped.
    pub fn has_drops(&self) -> bool {
        self.message.values().any(|f| matches!(f, MsgFault::Drop))
    }

    /// Whether any worker is scheduled for a hard SIGKILL (process
    /// mode only).
    pub fn has_kills(&self) -> bool {
        self.compute.values().any(|f| matches!(f, WorkerFault::Kill))
    }

    /// Whether any link partition is scheduled (process mode only).
    pub fn has_partitions(&self) -> bool {
        self.compute.values().any(|f| matches!(f, WorkerFault::Partition { .. }))
    }

    // --- builders (used by tests and FaultPlan::sampled) ---

    pub fn stall(mut self, worker: usize, epoch: usize, iter: usize, millis: u64) -> Self {
        self.compute.insert((worker, epoch, iter), WorkerFault::Stall { millis });
        self
    }

    pub fn die(mut self, worker: usize, epoch: usize, iter: usize) -> Self {
        self.compute.insert((worker, epoch, iter), WorkerFault::Die);
        self
    }

    pub fn kill(mut self, worker: usize, epoch: usize, iter: usize) -> Self {
        self.compute.insert((worker, epoch, iter), WorkerFault::Kill);
        self
    }

    pub fn partition(mut self, worker: usize, epoch: usize, iter: usize, millis: u64) -> Self {
        self.compute.insert((worker, epoch, iter), WorkerFault::Partition { millis });
        self
    }

    pub fn drop_msg(mut self, worker: usize, epoch: usize, iter: usize) -> Self {
        self.message.insert((worker, epoch, iter), MsgFault::Drop);
        self
    }

    pub fn delay_msg(mut self, worker: usize, epoch: usize, iter: usize, millis: u64) -> Self {
        self.message.insert((worker, epoch, iter), MsgFault::Delay { millis });
        self
    }

    // --- lookups (hot path: BTreeMap point query, empty plan is free) ---

    /// The compute fault scheduled for `worker` at `(epoch, iter)`.
    #[inline]
    pub fn worker_fault(&self, worker: usize, epoch: usize, iter: usize) -> Option<WorkerFault> {
        if self.compute.is_empty() {
            return None;
        }
        self.compute.get(&(worker, epoch, iter)).copied()
    }

    /// The message fault scheduled for `worker`'s send at `(epoch, iter)`.
    #[inline]
    pub fn message_fault(&self, worker: usize, epoch: usize, iter: usize) -> Option<MsgFault> {
        if self.message.is_empty() {
            return None;
        }
        self.message.get(&(worker, epoch, iter)).copied()
    }

    /// Expand seeded rates into a pinned schedule over `p` workers ×
    /// `epochs` × `p` inner iterations. Deterministic in `(seed, p,
    /// epochs, rates)`; at most `p - 1` deaths are scheduled so the
    /// ring always keeps a survivor to adopt the orphaned stripes.
    pub fn sampled(seed: u64, p: usize, epochs: usize, rates: &FaultRates) -> FaultPlan {
        let mut rng = Xoshiro256::new(seed ^ 0xFA17_7001);
        let mut plan = FaultPlan::new();
        let mut deaths = 0usize;
        for w in 0..p {
            for e in 0..epochs {
                for r in 0..p {
                    if rates.die > 0.0 && deaths + 1 < p && rng.bernoulli(rates.die) {
                        plan = plan.die(w, e, r);
                        deaths += 1;
                    } else if rates.stall > 0.0 && rng.bernoulli(rates.stall) {
                        plan = plan.stall(w, e, r, rates.stall_ms);
                    }
                    if rates.drop > 0.0 && rng.bernoulli(rates.drop) {
                        plan = plan.drop_msg(w, e, r);
                    } else if rates.delay > 0.0 && rng.bernoulli(rates.delay) {
                        plan = plan.delay_msg(w, e, r, rates.delay_ms);
                    }
                }
            }
        }
        plan
    }

    /// Parse an explicit-event spec. Grammar (comma-separated events):
    ///
    /// ```text
    /// die@W.E.I            worker W dies at (epoch E, iter I)
    /// kill@W.E.I           ... is SIGKILLed (process mode only)
    /// partition@W.E.I:MS   ... loses its link MS ms (process mode only)
    /// stall@W.E.I:MS       worker W sleeps MS milliseconds first
    /// drop@W.E.I           W's outgoing message at (E, I) is lost
    /// delay@W.E.I:MS       ... delayed MS milliseconds
    /// ```
    ///
    /// e.g. `die@1.2.0,stall@0.1.3:50`. The empty string is the empty
    /// plan. For the `rand:` rate form use [`FaultPlan::parse_with`].
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new();
        for ev in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = ev
                .split_once('@')
                .ok_or_else(|| format!("fault '{ev}': expected kind@worker.epoch.iter"))?;
            let (coord, ms) = match rest.split_once(':') {
                Some((c, ms)) => {
                    let ms = ms
                        .parse::<u64>()
                        .map_err(|_| format!("fault '{ev}': bad milliseconds '{ms}'"))?;
                    (c, Some(ms))
                }
                None => (rest, None),
            };
            let parts: Vec<&str> = coord.split('.').collect();
            if parts.len() != 3 {
                return Err(format!(
                    "fault '{ev}': coordinate must be worker.epoch.iter (0-based)"
                ));
            }
            let num = |s: &str| {
                s.parse::<usize>().map_err(|_| format!("fault '{ev}': bad index '{s}'"))
            };
            let (w, e, i) = (num(parts[0])?, num(parts[1])?, num(parts[2])?);
            plan = match (kind, ms) {
                ("die", None) => plan.die(w, e, i),
                ("kill", None) => plan.kill(w, e, i),
                ("drop", None) => plan.drop_msg(w, e, i),
                ("stall", ms) => plan.stall(w, e, i, ms.unwrap_or(20)),
                ("delay", ms) => plan.delay_msg(w, e, i, ms.unwrap_or(5)),
                ("partition", ms) => plan.partition(w, e, i, ms.unwrap_or(50)),
                ("die" | "kill" | "drop", Some(_)) => {
                    return Err(format!("fault '{ev}': {kind} takes no duration"))
                }
                _ => {
                    return Err(format!(
                        "fault '{ev}': unknown kind '{kind}' \
                         (die|kill|partition|stall|drop|delay)"
                    ))
                }
            };
        }
        Ok(plan)
    }

    /// Parse either the explicit-event grammar of [`FaultPlan::parse`]
    /// or the seeded rate form
    ///
    /// ```text
    /// rand:seed=7,die=0.01,stall=0.05,stall_ms=20,drop=0.01,delay=0.02,delay_ms=5
    /// ```
    ///
    /// which needs the run shape (`p`, `epochs`) to expand into pinned
    /// events via [`FaultPlan::sampled`].
    pub fn parse_with(spec: &str, p: usize, epochs: usize) -> Result<FaultPlan, String> {
        let Some(body) = spec.strip_prefix("rand:") else {
            return Self::parse(spec);
        };
        let mut seed = 0u64;
        let mut rates = FaultRates::default();
        for kv in body.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("faults rand spec '{kv}': expected key=value"))?;
            let f = || v.parse::<f64>().map_err(|_| format!("faults '{kv}': bad rate '{v}'"));
            let u = || v.parse::<u64>().map_err(|_| format!("faults '{kv}': bad value '{v}'"));
            match k {
                "seed" => seed = u()?,
                "stall" => rates.stall = f()?,
                "stall_ms" => rates.stall_ms = u()?,
                "die" => rates.die = f()?,
                "drop" => rates.drop = f()?,
                "delay" => rates.delay = f()?,
                "delay_ms" => rates.delay_ms = u()?,
                other => {
                    return Err(format!(
                        "faults rand spec: unknown key '{other}' \
                         (seed|stall|stall_ms|die|drop|delay|delay_ms)"
                    ))
                }
            }
        }
        for (name, r) in [
            ("stall", rates.stall),
            ("die", rates.die),
            ("drop", rates.drop),
            ("delay", rates.delay),
        ] {
            if !(0.0..=1.0).contains(&r) {
                return Err(format!("faults rand spec: {name} rate {r} not in [0, 1]"));
            }
        }
        Ok(Self::sampled(seed, p, epochs, &rates))
    }

    /// Canonical spec string: `parse(plan.spec())` round-trips, so a
    /// sampled plan can be recorded and replayed as explicit events.
    pub fn spec(&self) -> String {
        let mut out = String::new();
        let mut sep = "";
        for (&(w, e, i), f) in &self.compute {
            match f {
                WorkerFault::Die => {
                    let _ = write!(out, "{sep}die@{w}.{e}.{i}");
                }
                WorkerFault::Kill => {
                    let _ = write!(out, "{sep}kill@{w}.{e}.{i}");
                }
                WorkerFault::Stall { millis } => {
                    let _ = write!(out, "{sep}stall@{w}.{e}.{i}:{millis}");
                }
                WorkerFault::Partition { millis } => {
                    let _ = write!(out, "{sep}partition@{w}.{e}.{i}:{millis}");
                }
            }
            sep = ",";
        }
        for (&(w, e, i), f) in &self.message {
            match f {
                MsgFault::Drop => {
                    let _ = write!(out, "{sep}drop@{w}.{e}.{i}");
                }
                MsgFault::Delay { millis } => {
                    let _ = write!(out, "{sep}delay@{w}.{e}.{i}:{millis}");
                }
            }
            sep = ",";
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_has_no_faults() {
        let p = FaultPlan::new();
        assert!(p.is_empty());
        assert_eq!(p.worker_fault(0, 0, 0), None);
        assert_eq!(p.message_fault(3, 9, 2), None);
        assert!(!p.has_deaths());
        assert!(!p.has_drops());
        assert_eq!(FaultPlan::parse("").unwrap(), p);
    }

    #[test]
    fn builder_and_lookup() {
        let p = FaultPlan::new()
            .die(1, 2, 0)
            .stall(0, 1, 3, 50)
            .drop_msg(2, 0, 1)
            .delay_msg(3, 4, 2, 7);
        assert_eq!(p.worker_fault(1, 2, 0), Some(WorkerFault::Die));
        assert_eq!(p.worker_fault(0, 1, 3), Some(WorkerFault::Stall { millis: 50 }));
        assert_eq!(p.worker_fault(1, 2, 1), None);
        assert_eq!(p.message_fault(2, 0, 1), Some(MsgFault::Drop));
        assert_eq!(p.message_fault(3, 4, 2), Some(MsgFault::Delay { millis: 7 }));
        assert!(p.has_deaths());
        assert!(p.has_drops());
        assert_eq!(p.len(), 4);
    }

    #[test]
    fn parse_explicit_events() {
        let p = FaultPlan::parse("die@1.2.0, stall@0.1.3:50,delay@3.4.2:7,drop@2.0.1").unwrap();
        assert_eq!(
            p,
            FaultPlan::new()
                .die(1, 2, 0)
                .stall(0, 1, 3, 50)
                .delay_msg(3, 4, 2, 7)
                .drop_msg(2, 0, 1)
        );
        // Durations default when omitted.
        let q = FaultPlan::parse("stall@0.0.0,delay@0.0.1").unwrap();
        assert_eq!(q.worker_fault(0, 0, 0), Some(WorkerFault::Stall { millis: 20 }));
        assert_eq!(q.message_fault(0, 0, 1), Some(MsgFault::Delay { millis: 5 }));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "die",            // no coordinate
            "die@1.2",        // two indices
            "die@1.2.0:10",   // die takes no duration
            "drop@0.0.0:1",   // drop takes no duration
            "zap@0.0.0",      // unknown kind
            "stall@a.0.0:5",  // bad index
            "stall@0.0.0:xx", // bad millis
        ] {
            let err = FaultPlan::parse(bad).unwrap_err();
            assert!(err.contains(bad.split(',').next().unwrap()), "{bad}: {err}");
        }
    }

    #[test]
    fn spec_round_trips_including_sampled_plans() {
        let p = FaultPlan::new().die(1, 2, 0).stall(0, 1, 3, 50).drop_msg(2, 0, 1);
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);

        let rates =
            FaultRates { stall: 0.1, die: 0.02, drop: 0.05, delay: 0.05, ..Default::default() };
        let s = FaultPlan::sampled(9, 4, 6, &rates);
        assert!(!s.is_empty());
        assert_eq!(FaultPlan::parse(&s.spec()).unwrap(), s);
    }

    #[test]
    fn sampled_is_deterministic_in_seed() {
        let rates = FaultRates { stall: 0.2, die: 0.05, ..Default::default() };
        let a = FaultPlan::sampled(7, 4, 10, &rates);
        let b = FaultPlan::sampled(7, 4, 10, &rates);
        let c = FaultPlan::sampled(8, 4, 10, &rates);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn sampled_keeps_a_survivor() {
        // Even at die = 1.0 the plan must leave at least one worker
        // alive to adopt the orphaned stripes.
        for p in [1usize, 2, 4, 8] {
            let rates = FaultRates { die: 1.0, ..Default::default() };
            let plan = FaultPlan::sampled(3, p, 5, &rates);
            let dies = |w: usize| {
                (0..5).any(|e| (0..p).any(|r| plan.worker_fault(w, e, r) == Some(WorkerFault::Die)))
            };
            let deaths = (0..p).filter(|&w| dies(w)).count();
            assert!(deaths < p.max(1), "p={p}: {deaths} deaths");
        }
    }

    #[test]
    fn parse_kill_and_partition_events() {
        let p = FaultPlan::parse("kill@1.0.2,partition@0.1.0:40,partition@2.0.0").unwrap();
        assert_eq!(p.worker_fault(1, 0, 2), Some(WorkerFault::Kill));
        assert_eq!(p.worker_fault(0, 1, 0), Some(WorkerFault::Partition { millis: 40 }));
        // Partition duration defaults like stall/delay do.
        assert_eq!(p.worker_fault(2, 0, 0), Some(WorkerFault::Partition { millis: 50 }));
        assert!(p.has_kills());
        assert!(p.has_partitions());
        // A kill counts as a death (validation and engine guards key
        // off has_deaths), but a partition does not.
        assert!(p.has_deaths());
        assert!(!FaultPlan::parse("partition@0.0.0:10").unwrap().has_deaths());
        // kill takes no duration; spec round-trips the new kinds.
        assert!(FaultPlan::parse("kill@0.0.0:5").unwrap_err().contains("no duration"));
        assert_eq!(FaultPlan::parse(&p.spec()).unwrap(), p);
    }

    #[test]
    fn sampled_at_p1_schedules_zero_deaths() {
        // The survivor guarantee at the p = 1 edge: `deaths + 1 < p`
        // can never hold, so a rate-sampled plan over one worker must
        // contain no deaths at all — even at die = 1.0 — while other
        // fault kinds still sample freely.
        let rates = FaultRates { die: 1.0, stall: 1.0, ..Default::default() };
        for seed in 0..32u64 {
            for epochs in [1usize, 3, 7] {
                let plan = FaultPlan::sampled(seed, 1, epochs, &rates);
                assert!(!plan.has_deaths(), "seed {seed}, epochs {epochs}: death at p=1");
                // The die branch falls through to stall, so the single
                // worker is a straggler at every visit instead.
                for e in 0..epochs {
                    assert!(
                        matches!(plan.worker_fault(0, e, 0), Some(WorkerFault::Stall { .. })),
                        "seed {seed}: die fell through to nothing at epoch {e}"
                    );
                }
            }
        }
        // Same guarantee through the user-facing spec grammar.
        let via_spec = FaultPlan::parse_with("rand:seed=11,die=1.0", 1, 5).unwrap();
        assert!(!via_spec.has_deaths(), "rand: spec produced a death at p=1");
    }

    #[test]
    fn spec_plan_round_trip_property_at_small_p() {
        // Property at the p ∈ {1, 2} edges: for any sampled plan,
        // spec() → parse() → spec() is a fixed point and the plans
        // compare equal — the recorded-schedule story depends on a
        // sampled chaos run being replayable from its spec string.
        let rates = FaultRates {
            stall: 0.3,
            stall_ms: 7,
            die: 0.4,
            drop: 0.2,
            delay: 0.3,
            delay_ms: 2,
        };
        for p in [1usize, 2] {
            for seed in 0..50u64 {
                let plan = FaultPlan::sampled(seed, p, 4, &rates);
                let spec = plan.spec();
                let back = FaultPlan::parse(&spec).unwrap();
                assert_eq!(back, plan, "p={p} seed={seed}: spec '{spec}' did not round-trip");
                assert_eq!(back.spec(), spec, "p={p} seed={seed}: spec not a fixed point");
                if p == 1 {
                    assert!(!plan.has_deaths(), "p=1 survivor guarantee violated");
                }
            }
        }
    }

    #[test]
    fn parse_with_expands_rand_specs() {
        let a = FaultPlan::parse_with("rand:seed=7,stall=0.2,stall_ms=10,die=0.05", 4, 8).unwrap();
        let b = FaultPlan::parse_with("rand:seed=7,stall=0.2,stall_ms=10,die=0.05", 4, 8).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // Explicit grammar still works through parse_with.
        let c = FaultPlan::parse_with("die@0.1.0", 4, 8).unwrap();
        assert_eq!(c, FaultPlan::new().die(0, 1, 0));
        // Bad keys/rates are actionable errors.
        assert!(FaultPlan::parse_with("rand:zap=1", 2, 2).unwrap_err().contains("zap"));
        assert!(FaultPlan::parse_with("rand:die=1.5", 2, 2).unwrap_err().contains("1.5"));
    }
}
