//! Per-worker virtual clocks.
//!
//! Each simulated worker accumulates (a) measured wall-clock compute
//! time and (b) simulated communication time from the [`CostModel`].
//! The cluster-level virtual time of a bulk-synchronous phase is the
//! max across workers — the quantity the paper plots on its "time
//! spent" axes and the one Theorem 1's `(|Ω|T_u/p + T_c)T` bound
//! describes.

/// Virtual clock: compute + communication seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VirtualClock {
    pub compute_s: f64,
    pub comm_s: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    #[inline]
    pub fn add_compute(&mut self, secs: f64) {
        debug_assert!(secs >= 0.0);
        self.compute_s += secs;
    }

    #[inline]
    pub fn add_comm(&mut self, secs: f64) {
        debug_assert!(secs >= 0.0);
        self.comm_s += secs;
    }

    #[inline]
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s
    }

    /// Bulk synchronization: all workers wait for the slowest, so every
    /// clock jumps to the max. Returns the synchronized time.
    pub fn synchronize(clocks: &mut [VirtualClock]) -> f64 {
        let t = clocks.iter().map(|c| c.total()).fold(0.0, f64::max);
        for c in clocks.iter_mut() {
            // Waiting time is attributed to communication.
            let wait = t - c.total();
            c.comm_s += wait;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut c = VirtualClock::new();
        c.add_compute(1.5);
        c.add_comm(0.5);
        c.add_compute(0.25);
        assert!((c.compute_s - 1.75).abs() < 1e-12);
        assert!((c.comm_s - 0.5).abs() < 1e-12);
        assert!((c.total() - 2.25).abs() < 1e-12);
    }

    #[test]
    fn synchronize_aligns_to_max() {
        let mut clocks = vec![
            VirtualClock { compute_s: 1.0, comm_s: 0.0 },
            VirtualClock { compute_s: 3.0, comm_s: 0.5 },
            VirtualClock { compute_s: 0.0, comm_s: 0.0 },
        ];
        let t = VirtualClock::synchronize(&mut clocks);
        assert!((t - 3.5).abs() < 1e-12);
        for c in &clocks {
            assert!((c.total() - 3.5).abs() < 1e-12);
        }
        // Fast workers' wait shows up as comm time.
        assert!((clocks[2].comm_s - 3.5).abs() < 1e-12);
        assert_eq!(clocks[2].compute_s, 0.0);
    }

    #[test]
    fn synchronize_idempotent() {
        let mut clocks = vec![
            VirtualClock { compute_s: 2.0, comm_s: 0.0 },
            VirtualClock { compute_s: 1.0, comm_s: 0.0 },
        ];
        let t1 = VirtualClock::synchronize(&mut clocks);
        let snapshot = clocks.clone();
        let t2 = VirtualClock::synchronize(&mut clocks);
        assert_eq!(t1, t2);
        assert_eq!(clocks, snapshot);
    }
}
