//! Cluster network: a simulated topology and a real one.
//!
//! The paper runs DSO on 4–8 machines over MPI; this environment is a
//! single box, so two substitutes coexist (DESIGN.md §substitutions,
//! §Transport):
//!
//! * **Simulated topology** ([`router`], [`clock`]) — each worker is an
//!   OS thread, workers are grouped into "machines" (`machines × cores`
//!   as in the paper's "4 machines × 8 cores"), and every message
//!   carries a simulated transfer cost
//!
//!   ```text
//!       T_c(bytes) = latency + bytes / bandwidth
//!   ```
//!
//!   charged to the receiving worker's *virtual clock*. Intra-machine
//!   messages are free (shared memory), matching the paper's hybrid
//!   MPI+threads setup. Experiments report virtual time, which exposes
//!   exactly the `|Ω|T_u/p + T_c` trade-off of Theorem 1 without real
//!   network hardware. This is the fast path and the differential
//!   oracle for the real transport.
//!
//! * **Real transport** ([`wire`], [`transport`], [`supervisor`]) —
//!   `--mode dso-proc` runs one OS process per worker over Unix-domain
//!   sockets, with length-prefixed checksummed frames, delta-encoded
//!   token exchange, sequenced retransmission, heartbeat-based death
//!   detection, and a recorded schedule that replays serially to the
//!   bit-identical result. Here nothing is modeled: virtual time *is*
//!   wall time and `comm_bytes` counts bytes that actually crossed a
//!   socket.
//!
//! [`faults`] speaks to both: the same `FaultPlan` clock coordinates
//! drive simulated faults in the thread ring and real process kills,
//! link partitions, and stalls in the process ring.

pub mod clock;
pub mod faults;
pub mod router;
pub mod supervisor;
pub mod transport;
pub mod wire;

pub use clock::VirtualClock;
pub use faults::{FaultPlan, FaultRates, MsgFault, WorkerFault};
pub use router::{Backoff, NetStats, Recv, Router};
pub use supervisor::{replay_recorded_schedule, train_dso_proc_with, Replayed, Schedule};
pub use transport::{connect_with_backoff, ConnIn, FrameConn};

/// Lock a mutex, tolerating poison: a peer that panicked while holding
/// the lock must not cascade into every survivor (the engines recover
/// from worker panics; the data under these locks stays consistent
/// because workers push/pop whole tokens and stripes).
pub fn lock_tolerant<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Cost model for simulated transfers.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub latency_s: f64,
    /// Bytes per second.
    pub bandwidth_bps: f64,
    /// Workers per machine; messages between workers on the same
    /// machine cost nothing.
    pub cores_per_machine: usize,
}

impl CostModel {
    pub fn new(latency_us: f64, bandwidth_mbps: f64, cores_per_machine: usize) -> CostModel {
        assert!(latency_us >= 0.0 && bandwidth_mbps > 0.0 && cores_per_machine >= 1);
        CostModel {
            latency_s: latency_us * 1e-6,
            bandwidth_bps: bandwidth_mbps * 1e6,
            cores_per_machine,
        }
    }

    /// Zero-cost network (pure shared memory run).
    pub fn free() -> CostModel {
        CostModel { latency_s: 0.0, bandwidth_bps: f64::INFINITY, cores_per_machine: usize::MAX }
    }

    #[inline]
    pub fn machine_of(&self, worker: usize) -> usize {
        worker / self.cores_per_machine
    }

    /// Simulated seconds to move `bytes` from `from` to `to`.
    #[inline]
    pub fn transfer_secs(&self, from: usize, to: usize, bytes: usize) -> f64 {
        if self.machine_of(from) == self.machine_of(to) {
            0.0
        } else {
            self.latency_s + bytes as f64 / self.bandwidth_bps
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_grouping() {
        let cm = CostModel::new(100.0, 1000.0, 8);
        assert_eq!(cm.machine_of(0), 0);
        assert_eq!(cm.machine_of(7), 0);
        assert_eq!(cm.machine_of(8), 1);
        assert_eq!(cm.machine_of(31), 3);
    }

    #[test]
    fn intra_machine_free() {
        let cm = CostModel::new(100.0, 1000.0, 8);
        assert_eq!(cm.transfer_secs(0, 7, 1 << 20), 0.0);
    }

    #[test]
    fn inter_machine_latency_plus_bandwidth() {
        let cm = CostModel::new(100.0, 1.0, 1); // 1 MB/s, 100us
        let t = cm.transfer_secs(0, 1, 1_000_000);
        assert!((t - (100e-6 + 1.0)).abs() < 1e-9);
        // Empty message still pays latency.
        assert!((cm.transfer_secs(0, 1, 0) - 100e-6).abs() < 1e-12);
    }

    #[test]
    fn free_model_costs_nothing() {
        let cm = CostModel::free();
        assert_eq!(cm.transfer_secs(0, 999, usize::MAX / 2), 0.0);
    }

    #[test]
    fn cost_scales_linearly_in_bytes() {
        let cm = CostModel::new(0.0, 100.0, 1);
        let t1 = cm.transfer_secs(0, 1, 1000);
        let t2 = cm.transfer_secs(0, 1, 2000);
        assert!((t2 - 2.0 * t1).abs() < 1e-15);
    }
}
