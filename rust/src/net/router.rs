//! Message routing between simulated workers.
//!
//! A [`Router`] owns one mpsc channel per worker. Worker threads take
//! their `Endpoint` (receiver + sender handles to everyone) before
//! spawning. Sends are non-blocking; receives either block
//! ([`Endpoint::recv`]) or wait a bounded interval
//! ([`Endpoint::recv_timeout`]) — the bounded form is what the
//! fault-tolerant engines use, so a stalled or dead peer degrades
//! throughput instead of deadlocking the ring. Every transfer is
//! accounted in [`NetStats`] (messages, bytes, simulated seconds,
//! plus the degradation counters: dropped messages, bounded-wait
//! time, timeouts) so experiments can report communication volume
//! *and* straggler staleness.
//!
//! A send to a worker whose receiver is gone is **not** silently
//! lost: [`Endpoint::send`] hands the payload back so the caller can
//! route it into recovery (the async engine re-routes the token to a
//! surviving worker), and the drop is counted.

use super::CostModel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// A routed message: payload plus simulated arrival metadata.
pub struct Delivery<T> {
    pub from: usize,
    pub payload: T,
    /// Simulated transfer cost the receiver must add to its clock.
    pub comm_secs: f64,
    pub bytes: usize,
}

/// Shared network statistics.
#[derive(Debug, Default)]
pub struct NetStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    /// Total simulated comm microseconds (sum across links).
    pub sim_comm_us: AtomicU64,
    /// Sends whose receiver was gone (dead worker / hung-up peer).
    pub dropped_messages: AtomicU64,
    /// Cumulative bounded-wait receive time spent without data, in
    /// microseconds — the straggler staleness proxy the history's
    /// `wait_s` column reports.
    pub wait_us: AtomicU64,
    /// Number of bounded-wait receives that timed out.
    pub recv_timeouts: AtomicU64,
}

impl NetStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn total_sim_comm_secs(&self) -> f64 {
        self.sim_comm_us.load(Ordering::Relaxed) as f64 * 1e-6
    }

    pub fn total_dropped(&self) -> u64 {
        self.dropped_messages.load(Ordering::Relaxed)
    }

    pub fn total_wait_secs(&self) -> f64 {
        self.wait_us.load(Ordering::Relaxed) as f64 * 1e-6
    }

    pub fn total_timeouts(&self) -> u64 {
        self.recv_timeouts.load(Ordering::Relaxed)
    }
}

/// Outcome of a bounded-wait receive.
pub enum Recv<T> {
    Msg(Delivery<T>),
    /// Nothing arrived within the wait bound (counted on [`NetStats`]).
    Timeout,
    /// Every sender handle is gone; no message can ever arrive.
    Disconnected,
}

/// Exponential backoff for bounded-wait receive loops: start short so
/// an idle worker notices a token quickly, grow toward `cap` so a
/// starved worker does not spin, reset on every delivery.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    start_ms: u64,
    cur_ms: u64,
    cap_ms: u64,
}

impl Backoff {
    pub fn new(start_ms: u64, cap_ms: u64) -> Backoff {
        let start_ms = start_ms.max(1);
        Backoff { start_ms, cur_ms: start_ms, cap_ms: cap_ms.max(start_ms) }
    }

    /// The next wait bound (doubles toward the cap).
    pub fn next(&mut self) -> Duration {
        let d = Duration::from_millis(self.cur_ms);
        self.cur_ms = (self.cur_ms * 2).min(self.cap_ms);
        d
    }

    /// Call after a successful receive.
    pub fn reset(&mut self) {
        self.cur_ms = self.start_ms;
    }
}

/// One worker's handle onto the network.
pub struct Endpoint<T> {
    pub id: usize,
    rx: Receiver<Delivery<T>>,
    txs: Vec<Sender<Delivery<T>>>,
    cost: CostModel,
    stats: Arc<NetStats>,
}

impl<T> Endpoint<T> {
    /// Send `payload` of logical size `bytes` to worker `to`.
    ///
    /// If `to`'s receiver is gone (dead or exited worker) the message
    /// is not lost: the payload comes back as `Err` so the caller can
    /// route it into recovery, and the drop is counted on [`NetStats`].
    #[must_use = "a failed send hands the payload back for recovery — don't lose it"]
    pub fn send(&self, to: usize, payload: T, bytes: usize) -> Result<(), T> {
        let comm_secs = self.cost.transfer_secs(self.id, to, bytes);
        match self.txs[to].send(Delivery { from: self.id, payload, comm_secs, bytes }) {
            Ok(()) => {
                self.stats.messages.fetch_add(1, Ordering::Relaxed);
                self.stats.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                self.stats
                    .sim_comm_us
                    .fetch_add((comm_secs * 1e6) as u64, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                self.stats.dropped_messages.fetch_add(1, Ordering::Relaxed);
                Err(e.0.payload)
            }
        }
    }

    /// Blocking receive.
    pub fn recv(&self) -> Option<Delivery<T>> {
        self.rx.recv().ok()
    }

    /// Bounded-wait receive: wait at most `timeout` for a delivery.
    /// Timeouts are accounted on [`NetStats`] (`recv_timeouts`, and
    /// the elapsed bound on `wait_us`) — the straggler staleness the
    /// history's `wait_s` column surfaces.
    pub fn recv_timeout(&self, timeout: Duration) -> Recv<T> {
        match self.rx.recv_timeout(timeout) {
            Ok(d) => Recv::Msg(d),
            Err(RecvTimeoutError::Timeout) => {
                self.stats.recv_timeouts.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .wait_us
                    .fetch_add(timeout.as_micros() as u64, Ordering::Relaxed);
                Recv::Timeout
            }
            Err(RecvTimeoutError::Disconnected) => Recv::Disconnected,
        }
    }

    pub fn try_recv(&self) -> Option<Delivery<T>> {
        self.rx.try_recv().ok()
    }
}

/// Builder for a p-worker network.
pub struct Router<T> {
    endpoints: Vec<Endpoint<T>>,
    stats: Arc<NetStats>,
}

impl<T> Router<T> {
    pub fn new(p: usize, cost: CostModel) -> Router<T> {
        let stats = Arc::new(NetStats::default());
        let mut txs = Vec::with_capacity(p);
        let mut rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = std::sync::mpsc::channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(id, rx)| Endpoint {
                id,
                rx,
                txs: txs.clone(),
                cost,
                stats: stats.clone(),
            })
            .collect();
        Router { endpoints, stats }
    }

    /// Take all endpoints (one per worker thread). Call once.
    pub fn take_endpoints(&mut self) -> Vec<Endpoint<T>> {
        std::mem::take(&mut self.endpoints)
    }

    pub fn stats(&self) -> Arc<NetStats> {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let mut router: Router<Vec<f32>> = Router::new(2, CostModel::new(10.0, 100.0, 1));
        let mut eps = router.take_endpoints();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, vec![1.0, 2.0], 8).unwrap();
        let d = e1.recv().unwrap();
        assert_eq!(d.from, 0);
        assert_eq!(d.payload, vec![1.0, 2.0]);
        assert_eq!(d.bytes, 8);
        assert!(d.comm_secs > 0.0);
    }

    #[test]
    fn stats_accumulate() {
        let mut router: Router<u32> = Router::new(3, CostModel::new(100.0, 1.0, 1));
        let stats = router.stats();
        let eps = router.take_endpoints();
        eps[0].send(1, 7, 1000).unwrap();
        eps[0].send(2, 8, 2000).unwrap();
        eps[1].recv().unwrap();
        eps[2].recv().unwrap();
        assert_eq!(stats.total_messages(), 2);
        assert_eq!(stats.total_bytes(), 3000);
        assert!(stats.total_sim_comm_secs() > 2.0 * 100e-6);
    }

    #[test]
    fn intra_machine_message_free_but_counted() {
        let mut router: Router<u32> = Router::new(4, CostModel::new(100.0, 1.0, 2));
        let stats = router.stats();
        let eps = router.take_endpoints();
        eps[0].send(1, 1, 500).unwrap(); // same machine (cores_per_machine = 2)
        let d = eps[1].recv().unwrap();
        assert_eq!(d.comm_secs, 0.0);
        assert_eq!(stats.total_bytes(), 500);
    }

    #[test]
    fn fifo_per_sender() {
        let mut router: Router<u32> = Router::new(2, CostModel::free());
        let eps = router.take_endpoints();
        for k in 0..10 {
            eps[0].send(1, k, 4).unwrap();
        }
        for k in 0..10 {
            assert_eq!(eps[1].recv().unwrap().payload, k);
        }
    }

    #[test]
    fn cross_thread_ring_rotation() {
        // 4 workers pass a token around the ring twice.
        let p = 4;
        let mut router: Router<u64> = Router::new(p, CostModel::new(1.0, 1000.0, 1));
        let eps = router.take_endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let mut token = ep.id as u64;
                    for _ in 0..2 * p {
                        let to = (ep.id + p - 1) % p;
                        ep.send(to, token, 8).unwrap();
                        token = ep.recv().unwrap().payload;
                    }
                    token
                })
            })
            .collect();
        let finals: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // After 2p hops each token returns home.
        assert_eq!(finals, vec![0, 1, 2, 3]);
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut router: Router<u32> = Router::new(2, CostModel::free());
        let eps = router.take_endpoints();
        assert!(eps[1].try_recv().is_none());
        eps[0].send(1, 5, 4).unwrap();
        // Message is in the channel immediately (sim time is virtual).
        assert_eq!(eps[1].try_recv().unwrap().payload, 5);
    }

    #[test]
    fn send_to_dead_receiver_returns_payload_and_counts_drop() {
        let mut router: Router<Vec<f32>> = Router::new(2, CostModel::free());
        let stats = router.stats();
        let mut eps = router.take_endpoints();
        drop(eps.pop()); // worker 1 is gone
        let e0 = eps.pop().unwrap();
        let token = vec![1.0f32, 2.0];
        let back = e0.send(1, token.clone(), 8).unwrap_err();
        assert_eq!(back, token, "payload must come back for recovery");
        assert_eq!(stats.total_dropped(), 1);
        // Failed sends are not counted as delivered traffic.
        assert_eq!(stats.total_messages(), 0);
        assert_eq!(stats.total_bytes(), 0);
    }

    #[test]
    fn recv_timeout_counts_waits_and_sees_messages() {
        let mut router: Router<u32> = Router::new(2, CostModel::free());
        let stats = router.stats();
        let eps = router.take_endpoints();
        match eps[1].recv_timeout(Duration::from_millis(1)) {
            Recv::Timeout => {}
            _ => panic!("empty queue must time out"),
        }
        assert_eq!(stats.total_timeouts(), 1);
        assert!(stats.total_wait_secs() >= 0.9e-3);
        eps[0].send(1, 5, 4).unwrap();
        match eps[1].recv_timeout(Duration::from_millis(50)) {
            Recv::Msg(d) => assert_eq!(d.payload, 5),
            _ => panic!("queued message must be delivered"),
        }
        // Only genuine timeouts are counted, not deliveries.
        assert_eq!(stats.total_timeouts(), 1);
    }

    #[test]
    fn backoff_doubles_to_cap_and_resets() {
        let mut b = Backoff::new(1, 8);
        let waits: Vec<u64> = (0..5).map(|_| b.next().as_millis() as u64).collect();
        assert_eq!(waits, vec![1, 2, 4, 8, 8]);
        b.reset();
        assert_eq!(b.next().as_millis(), 1);
        // Degenerate bounds are clamped sane.
        let mut z = Backoff::new(0, 0);
        assert_eq!(z.next().as_millis(), 1);
    }
}
