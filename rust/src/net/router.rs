//! Message routing between simulated workers.
//!
//! A [`Router`] owns one mpsc channel per worker. Worker threads take
//! their `Endpoint` (receiver + sender handles to everyone) before
//! spawning. Sends are non-blocking; receives block until a message
//! arrives — exactly the semantics DSO's ring rotation needs (worker q
//! cannot start inner iteration r+1 before its next w block arrives).
//! Every transfer is accounted in [`NetStats`] (messages, bytes,
//! simulated seconds) so experiments can report communication volume.

use super::CostModel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// A routed message: payload plus simulated arrival metadata.
pub struct Delivery<T> {
    pub from: usize,
    pub payload: T,
    /// Simulated transfer cost the receiver must add to its clock.
    pub comm_secs: f64,
    pub bytes: usize,
}

/// Shared network statistics.
#[derive(Debug, Default)]
pub struct NetStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    /// Total simulated comm microseconds (sum across links).
    pub sim_comm_us: AtomicU64,
}

impl NetStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn total_sim_comm_secs(&self) -> f64 {
        self.sim_comm_us.load(Ordering::Relaxed) as f64 * 1e-6
    }
}

/// One worker's handle onto the network.
pub struct Endpoint<T> {
    pub id: usize,
    rx: Receiver<Delivery<T>>,
    txs: Vec<Sender<Delivery<T>>>,
    cost: CostModel,
    stats: Arc<NetStats>,
}

impl<T> Endpoint<T> {
    /// Send `payload` of logical size `bytes` to worker `to`.
    pub fn send(&self, to: usize, payload: T, bytes: usize) {
        let comm_secs = self.cost.transfer_secs(self.id, to, bytes);
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.stats
            .sim_comm_us
            .fetch_add((comm_secs * 1e6) as u64, Ordering::Relaxed);
        // Receiver gone (e.g. panic elsewhere) — drop silently; the
        // engine surfaces the original panic via thread join.
        let _ = self.txs[to].send(Delivery { from: self.id, payload, comm_secs, bytes });
    }

    /// Blocking receive.
    pub fn recv(&self) -> Option<Delivery<T>> {
        self.rx.recv().ok()
    }

    pub fn try_recv(&self) -> Option<Delivery<T>> {
        self.rx.try_recv().ok()
    }
}

/// Builder for a p-worker network.
pub struct Router<T> {
    endpoints: Vec<Endpoint<T>>,
    stats: Arc<NetStats>,
}

impl<T> Router<T> {
    pub fn new(p: usize, cost: CostModel) -> Router<T> {
        let stats = Arc::new(NetStats::default());
        let mut txs = Vec::with_capacity(p);
        let mut rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = std::sync::mpsc::channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(id, rx)| Endpoint {
                id,
                rx,
                txs: txs.clone(),
                cost,
                stats: stats.clone(),
            })
            .collect();
        Router { endpoints, stats }
    }

    /// Take all endpoints (one per worker thread). Call once.
    pub fn take_endpoints(&mut self) -> Vec<Endpoint<T>> {
        std::mem::take(&mut self.endpoints)
    }

    pub fn stats(&self) -> Arc<NetStats> {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let mut router: Router<Vec<f32>> = Router::new(2, CostModel::new(10.0, 100.0, 1));
        let mut eps = router.take_endpoints();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        e0.send(1, vec![1.0, 2.0], 8);
        let d = e1.recv().unwrap();
        assert_eq!(d.from, 0);
        assert_eq!(d.payload, vec![1.0, 2.0]);
        assert_eq!(d.bytes, 8);
        assert!(d.comm_secs > 0.0);
    }

    #[test]
    fn stats_accumulate() {
        let mut router: Router<u32> = Router::new(3, CostModel::new(100.0, 1.0, 1));
        let stats = router.stats();
        let eps = router.take_endpoints();
        eps[0].send(1, 7, 1000);
        eps[0].send(2, 8, 2000);
        eps[1].recv().unwrap();
        eps[2].recv().unwrap();
        assert_eq!(stats.total_messages(), 2);
        assert_eq!(stats.total_bytes(), 3000);
        assert!(stats.total_sim_comm_secs() > 2.0 * 100e-6);
    }

    #[test]
    fn intra_machine_message_free_but_counted() {
        let mut router: Router<u32> = Router::new(4, CostModel::new(100.0, 1.0, 2));
        let stats = router.stats();
        let eps = router.take_endpoints();
        eps[0].send(1, 1, 500); // same machine (cores_per_machine = 2)
        let d = eps[1].recv().unwrap();
        assert_eq!(d.comm_secs, 0.0);
        assert_eq!(stats.total_bytes(), 500);
    }

    #[test]
    fn fifo_per_sender() {
        let mut router: Router<u32> = Router::new(2, CostModel::free());
        let eps = router.take_endpoints();
        for k in 0..10 {
            eps[0].send(1, k, 4);
        }
        for k in 0..10 {
            assert_eq!(eps[1].recv().unwrap().payload, k);
        }
    }

    #[test]
    fn cross_thread_ring_rotation() {
        // 4 workers pass a token around the ring twice.
        let p = 4;
        let mut router: Router<u64> = Router::new(p, CostModel::new(1.0, 1000.0, 1));
        let eps = router.take_endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let mut token = ep.id as u64;
                    for _ in 0..2 * p {
                        let to = (ep.id + p - 1) % p;
                        ep.send(to, token, 8);
                        token = ep.recv().unwrap().payload;
                    }
                    token
                })
            })
            .collect();
        let finals: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // After 2p hops each token returns home.
        assert_eq!(finals, vec![0, 1, 2, 3]);
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut router: Router<u32> = Router::new(2, CostModel::free());
        let eps = router.take_endpoints();
        assert!(eps[1].try_recv().is_none());
        eps[0].send(1, 5, 4);
        // Message is in the channel immediately (sim time is virtual).
        assert_eq!(eps[1].try_recv().unwrap().payload, 5);
    }
}
