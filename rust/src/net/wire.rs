//! Wire format for the multi-process DSO transport (DESIGN.md
//! §Transport).
//!
//! Framing: every message travels as
//! `[u32 payload_len LE][u64 FNV-1a(payload) LE][payload]` — length-
//! prefixed so the stream stays TCP-ready (no datagram boundaries are
//! assumed even though the local transport is a Unix-domain socket),
//! and checksummed so a torn or bit-flipped frame is *rejected* at the
//! receiver and repaired by the Nack → resend protocol in
//! [`super::transport`] instead of silently perturbing the saddle
//! state.
//!
//! Payloads use a tagged binary codec with explicit little-endian
//! byte order and floats carried as IEEE-754 bit patterns, so the
//! exact `f32` state of w-stripe tokens crosses the process boundary
//! bit-for-bit — the recorded-schedule replay (Lemma 2 pinning in
//! [`super::supervisor`]) depends on this. Token arrays are
//! delta-encoded against the copy both ends already hold ([`Delta`]):
//! a `Deliver` ships the full block, and the `Fwd` that answers it
//! sends only the entries the sweep changed when that is smaller.
//!
//! The worker bootstrap rides the same codec: [`emit_config`] writes
//! the subset of [`TrainConfig`] a worker process needs to rebuild
//! `DsoSetup` deterministically, and the dataset ships as libsvm text
//! (`data::libsvm` round-trips labels and values exactly).

use crate::config::TrainConfig;
use std::io::{self, Read, Write};

/// Upper bound on a frame payload. A length prefix above this is
/// treated as corruption — it protects the receiver from unbounded
/// allocation on a garbled header.
pub const MAX_FRAME: usize = 1 << 28;

/// Frame header size: u32 length + u64 checksum.
pub const FRAME_HEADER: usize = 12;

/// 64-bit FNV-1a over the payload — the same hash family the
/// checkpoint fingerprint uses; cheap, dependency-free, and plenty
/// for torn-frame detection (cryptographic integrity is not the
/// goal on a local socket).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Outcome of reading one frame off a stream.
#[derive(Debug)]
pub enum FrameIn {
    /// A complete frame whose checksum verified.
    Frame(Vec<u8>),
    /// A complete frame whose checksum (or length prefix) did not
    /// verify; `wire_bytes` is what was consumed. The connection
    /// layer answers with a Nack so the sender retransmits.
    Corrupt { wire_bytes: usize },
    /// Clean end of stream (peer exited or closed the socket) — also
    /// returned for a frame torn mid-transfer by a dying peer.
    Eof,
    /// No frame started within the socket's read timeout.
    TimedOut,
}

enum Fill {
    Full,
    Eof,
    TimedOut,
}

/// Read exactly `buf.len()` bytes. `at_start` marks a read at a frame
/// boundary: only there does a timeout surface as `TimedOut` — once a
/// frame has begun, the sender has already written the rest, so we
/// keep waiting for it (a peer that dies mid-frame closes the socket
/// and surfaces as `Eof` instead).
fn fill(r: &mut impl Read, buf: &mut [u8], at_start: bool) -> io::Result<Fill> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return Ok(Fill::Eof),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                if at_start && got == 0 {
                    return Ok(Fill::TimedOut);
                }
                // Mid-frame timeout: the remainder is in flight.
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Fill::Full)
}

fn u32_le(b: &[u8]) -> u32 {
    let mut x = [0u8; 4];
    x.copy_from_slice(&b[..4]);
    u32::from_le_bytes(x)
}

fn u64_le(b: &[u8]) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[..8]);
    u64::from_le_bytes(x)
}

/// Write one frame (header + payload) and flush. Returns the bytes
/// put on the wire.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<usize> {
    let mut hdr = [0u8; FRAME_HEADER];
    hdr[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    hdr[4..].copy_from_slice(&fnv1a(payload).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(FRAME_HEADER + payload.len())
}

/// Read one frame. Timeouts are only reported at a frame boundary;
/// see [`fill`].
pub fn read_frame(r: &mut impl Read) -> io::Result<FrameIn> {
    let mut hdr = [0u8; FRAME_HEADER];
    match fill(r, &mut hdr, true)? {
        Fill::Eof => return Ok(FrameIn::Eof),
        Fill::TimedOut => return Ok(FrameIn::TimedOut),
        Fill::Full => {}
    }
    let len = u32_le(&hdr) as usize;
    let want = u64_le(&hdr[4..]);
    if len > MAX_FRAME {
        // Garbled length: the stream has lost framing. Report it as
        // corruption without consuming further — the connection layer
        // treats repeated corruption as a dead link.
        return Ok(FrameIn::Corrupt { wire_bytes: FRAME_HEADER });
    }
    let mut payload = vec![0u8; len];
    match fill(r, &mut payload, false)? {
        Fill::Full => {}
        _ => return Ok(FrameIn::Eof),
    }
    if fnv1a(&payload) != want {
        return Ok(FrameIn::Corrupt { wire_bytes: FRAME_HEADER + len });
    }
    Ok(FrameIn::Frame(payload))
}

/// Decode failure: checksum verified but the payload does not parse
/// as a known message — protocol skew or corruption the checksum
/// missed. The connection layer handles it like a corrupt frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeErr(pub String);

impl std::fmt::Display for DecodeErr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode: {}", self.0)
    }
}

impl std::error::Error for DecodeErr {}

// ---- payload codec -------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_bool(b: &mut Vec<u8>, v: bool) {
    b.push(v as u8);
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    put_u32(b, v.to_bits());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_f32s(b: &mut Vec<u8>, xs: &[f32]) {
    put_u32(b, xs.len() as u32);
    for &x in xs {
        put_f32(b, x);
    }
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    put_u64(b, v.to_bits());
}

fn put_f64s(b: &mut Vec<u8>, xs: &[f64]) {
    put_u32(b, xs.len() as u32);
    for &x in xs {
        put_f64(b, x);
    }
}

/// Bounds-checked payload reader.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeErr> {
        if self.pos + n > self.buf.len() {
            return Err(DecodeErr(format!(
                "truncated payload: wanted {n} at {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DecodeErr> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, DecodeErr> {
        Ok(u32_le(self.take(4)?))
    }

    fn u64(&mut self) -> Result<u64, DecodeErr> {
        Ok(u64_le(self.take(8)?))
    }

    fn bool(&mut self) -> Result<bool, DecodeErr> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(DecodeErr(format!("bad bool byte {v}"))),
        }
    }

    fn f32(&mut self) -> Result<f32, DecodeErr> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn str(&mut self) -> Result<String, DecodeErr> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| DecodeErr(format!("bad utf8: {e}")))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, DecodeErr> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 4 {
            return Err(DecodeErr(format!("f32 vector length {n} out of range")));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    fn f64(&mut self) -> Result<f64, DecodeErr> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f64s(&mut self) -> Result<Vec<f64>, DecodeErr> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME / 8 {
            return Err(DecodeErr(format!("f64 vector length {n} out of range")));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn done(&self) -> Result<(), DecodeErr> {
        if self.pos != self.buf.len() {
            return Err(DecodeErr(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---- delta encoding ------------------------------------------------

/// A delta-encoded `f32` array: either the full vector or the sparse
/// set of entries whose *bit pattern* changed relative to a baseline
/// both ends hold. Comparison is on bits, not values, so `-0.0` vs
/// `0.0` and NaN payloads survive the round trip and replay stays
/// bit-exact.
#[derive(Debug, Clone, PartialEq)]
pub enum Delta {
    Full(Vec<f32>),
    Sparse { len: u32, changes: Vec<(u32, f32)> },
}

impl Delta {
    /// Encode `new` against `base`, picking whichever form is smaller
    /// on the wire (sparse entries cost 8 bytes vs 4 for a dense one).
    pub fn encode(base: &[f32], new: &[f32]) -> Delta {
        if base.len() != new.len() {
            return Delta::Full(new.to_vec());
        }
        let changes: Vec<(u32, f32)> = new
            .iter()
            .zip(base.iter())
            .enumerate()
            .filter(|(_, (n, b))| n.to_bits() != b.to_bits())
            .map(|(i, (n, _))| (i as u32, *n))
            .collect();
        if 8 * changes.len() < 4 * new.len() {
            Delta::Sparse { len: new.len() as u32, changes }
        } else {
            Delta::Full(new.to_vec())
        }
    }

    /// Apply onto the baseline in place.
    pub fn apply(&self, base: &mut Vec<f32>) -> Result<(), DecodeErr> {
        match self {
            Delta::Full(v) => {
                base.clear();
                base.extend_from_slice(v);
                Ok(())
            }
            Delta::Sparse { len, changes } => {
                if base.len() != *len as usize {
                    return Err(DecodeErr(format!(
                        "sparse delta for length {len} applied to baseline of {}",
                        base.len()
                    )));
                }
                for &(i, v) in changes {
                    let i = i as usize;
                    if i >= base.len() {
                        return Err(DecodeErr(format!("delta index {i} out of range")));
                    }
                    base[i] = v;
                }
                Ok(())
            }
        }
    }

    fn put(&self, b: &mut Vec<u8>) {
        match self {
            Delta::Full(v) => {
                put_u8(b, 0);
                put_f32s(b, v);
            }
            Delta::Sparse { len, changes } => {
                put_u8(b, 1);
                put_u32(b, *len);
                put_u32(b, changes.len() as u32);
                for &(i, v) in changes {
                    put_u32(b, i);
                    put_f32(b, v);
                }
            }
        }
    }

    fn get(rd: &mut Rd<'_>) -> Result<Delta, DecodeErr> {
        match rd.u8()? {
            0 => Ok(Delta::Full(rd.f32s()?)),
            1 => {
                let len = rd.u32()?;
                let n = rd.u32()? as usize;
                if n > MAX_FRAME / 8 {
                    return Err(DecodeErr(format!("delta change count {n} out of range")));
                }
                let mut changes = Vec::with_capacity(n);
                for _ in 0..n {
                    let i = rd.u32()?;
                    let v = rd.f32()?;
                    changes.push((i, v));
                }
                Ok(Delta::Sparse { len, changes })
            }
            t => Err(DecodeErr(format!("unknown delta tag {t}"))),
        }
    }
}

// ---- messages ------------------------------------------------------

/// One row stripe's state on the wire (α block + its AdaGrad
/// accumulator, keyed by home partition index `q`).
#[derive(Debug, Clone, PartialEq)]
pub struct StripeMsg {
    pub q: u32,
    pub alpha: Vec<f32>,
    pub a_acc: Vec<f32>,
}

impl StripeMsg {
    fn put(&self, b: &mut Vec<u8>) {
        put_u32(b, self.q);
        put_f32s(b, &self.alpha);
        put_f32s(b, &self.a_acc);
    }

    fn get(rd: &mut Rd<'_>) -> Result<StripeMsg, DecodeErr> {
        Ok(StripeMsg { q: rd.u32()?, alpha: rd.f32s()?, a_acc: rd.f32s()? })
    }
}

fn put_stripes(b: &mut Vec<u8>, stripes: &[StripeMsg]) {
    put_u32(b, stripes.len() as u32);
    for s in stripes {
        s.put(b);
    }
}

fn get_stripes(rd: &mut Rd<'_>) -> Result<Vec<StripeMsg>, DecodeErr> {
    let n = rd.u32()? as usize;
    if n > 1 << 20 {
        return Err(DecodeErr(format!("stripe count {n} out of range")));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(StripeMsg::get(rd)?);
    }
    Ok(out)
}

const T_HELLO: u8 = 1;
const T_START: u8 = 2;
const T_READY: u8 = 3;
const T_DELIVER: u8 = 4;
const T_ADOPT: u8 = 5;
const T_FWD: u8 = 6;
const T_ACK: u8 = 7;
const T_NACK: u8 = 8;
const T_HEARTBEAT: u8 = 9;
const T_BYE: u8 = 10;
const T_KILLME: u8 = 11;
const T_SHUTDOWN: u8 = 12;
// Serving protocol (rust/src/serve) — rides the same framed transport.
const T_PREDICT: u8 = 13;
const T_SCORES: u8 = 14;
const T_RELOAD: u8 = 15;
const T_STATS: u8 = 16;
const T_STATS_REPLY: u8 = 17;
const T_SERVE_ERR: u8 = 18;

/// Protocol messages. Coordinator → worker: `Start`, `Deliver`,
/// `Adopt`, `Ack` (of `Fwd` seqs), `Nack`, `Shutdown`. Worker →
/// coordinator: `Hello`, `Ready`, `Fwd`, `Ack` (of coordinator seqs),
/// `Nack`, `Heartbeat`, `Bye` (graceful injected death), `KillMe`
/// (requests a real SIGKILL at a `kill@` fault coordinate, so the
/// worker-local fault clock stays deterministic while the signal
/// itself comes from the supervising parent).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// First frame on every (re)connection.
    Hello { worker: u32 },
    /// Bootstrap: everything a worker needs to rebuild `DsoSetup`
    /// deterministically, plus the run fingerprint it must echo.
    Start {
        fingerprint: u64,
        heartbeat_ms: u64,
        cfg_toml: String,
        ds_name: String,
        d: u64,
        libsvm: String,
        /// Out-of-core handoff: when nonempty, the worker mmaps this
        /// `.dsoblk` cache instead of parsing `libsvm` (which is then
        /// empty — the shard never crosses the socket).
        cache_path: String,
    },
    /// Handshake reply: the worker's independently recomputed
    /// fingerprint. A mismatch aborts the run (foreign worker).
    Ready { worker: u32, fingerprint: u64 },
    /// A w-block token delivered for one visit (always full state —
    /// the delivered copy is the baseline the `Fwd` delta refers to).
    Deliver { seq: u64, block_id: u32, hops: u64, w: Vec<f32>, acc: Vec<f32> },
    /// Orphaned stripes reassigned to this worker after a peer death.
    Adopt { seq: u64, stripes: Vec<StripeMsg> },
    /// A completed visit: the token comes back delta-encoded against
    /// the delivered baseline, with the sender's updated stripe state
    /// piggybacked so the coordinator's authoritative copy is always
    /// exactly "state as of the last completed sweep".
    Fwd {
        seq: u64,
        visit: u64,
        updates: u64,
        dropped: bool,
        block_id: u32,
        dw: Delta,
        dacc: Delta,
        stripes: Vec<StripeMsg>,
    },
    Ack { seq: u64 },
    /// Request retransmission of every unacked frame from `seq` on.
    Nack { seq: u64 },
    Heartbeat,
    Bye,
    KillMe,
    Shutdown,
    /// Serving: a batch of libsvm-formatted rows to score (labels, when
    /// present, are parsed and ignored). `id` is an opaque client token
    /// echoed on the reply so pipelined requests pair up.
    Predict { id: u64, batch: String },
    /// Serving reply: one f64 score per parsed request row, in row
    /// order. Scores cross the wire as IEEE-754 bit patterns (the same
    /// contract as the f32 token state), so client-side values are
    /// bit-identical to the server's fold.
    Scores { id: u64, scores: Vec<f64> },
    /// Serving: hot-swap the model from `path` (typically after a
    /// warm-start retrain). Acked on success, `ServeError` otherwise —
    /// the previous model keeps serving on failure.
    Reload { path: String },
    /// Serving: request the per-instance counters.
    StatsReq,
    /// Serving reply: per-instance request counters plus the backend
    /// recorded at startup and the current model dimension.
    StatsReply {
        served: u64,
        rows: u64,
        errors: u64,
        reloads: u64,
        total_latency_us: u64,
        max_latency_us: u64,
        backend: String,
        d: u64,
    },
    /// Serving reply: a request-scoped failure (parse error, dimension
    /// mismatch, unreadable model). The connection stays up; `id`
    /// echoes the failing request (0 for `Reload`).
    ServeError { id: u64, message: String },
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Msg::Hello { worker } => {
                put_u8(&mut b, T_HELLO);
                put_u32(&mut b, *worker);
            }
            Msg::Start { fingerprint, heartbeat_ms, cfg_toml, ds_name, d, libsvm, cache_path } => {
                put_u8(&mut b, T_START);
                put_u64(&mut b, *fingerprint);
                put_u64(&mut b, *heartbeat_ms);
                put_str(&mut b, cfg_toml);
                put_str(&mut b, ds_name);
                put_u64(&mut b, *d);
                put_str(&mut b, libsvm);
                put_str(&mut b, cache_path);
            }
            Msg::Ready { worker, fingerprint } => {
                put_u8(&mut b, T_READY);
                put_u32(&mut b, *worker);
                put_u64(&mut b, *fingerprint);
            }
            Msg::Deliver { seq, block_id, hops, w, acc } => {
                put_u8(&mut b, T_DELIVER);
                put_u64(&mut b, *seq);
                put_u32(&mut b, *block_id);
                put_u64(&mut b, *hops);
                put_f32s(&mut b, w);
                put_f32s(&mut b, acc);
            }
            Msg::Adopt { seq, stripes } => {
                put_u8(&mut b, T_ADOPT);
                put_u64(&mut b, *seq);
                put_stripes(&mut b, stripes);
            }
            Msg::Fwd { seq, visit, updates, dropped, block_id, dw, dacc, stripes } => {
                put_u8(&mut b, T_FWD);
                put_u64(&mut b, *seq);
                put_u64(&mut b, *visit);
                put_u64(&mut b, *updates);
                put_bool(&mut b, *dropped);
                put_u32(&mut b, *block_id);
                dw.put(&mut b);
                dacc.put(&mut b);
                put_stripes(&mut b, stripes);
            }
            Msg::Ack { seq } => {
                put_u8(&mut b, T_ACK);
                put_u64(&mut b, *seq);
            }
            Msg::Nack { seq } => {
                put_u8(&mut b, T_NACK);
                put_u64(&mut b, *seq);
            }
            Msg::Heartbeat => put_u8(&mut b, T_HEARTBEAT),
            Msg::Bye => put_u8(&mut b, T_BYE),
            Msg::KillMe => put_u8(&mut b, T_KILLME),
            Msg::Shutdown => put_u8(&mut b, T_SHUTDOWN),
            Msg::Predict { id, batch } => {
                put_u8(&mut b, T_PREDICT);
                put_u64(&mut b, *id);
                put_str(&mut b, batch);
            }
            Msg::Scores { id, scores } => {
                put_u8(&mut b, T_SCORES);
                put_u64(&mut b, *id);
                put_f64s(&mut b, scores);
            }
            Msg::Reload { path } => {
                put_u8(&mut b, T_RELOAD);
                put_str(&mut b, path);
            }
            Msg::StatsReq => put_u8(&mut b, T_STATS),
            Msg::StatsReply {
                served,
                rows,
                errors,
                reloads,
                total_latency_us,
                max_latency_us,
                backend,
                d,
            } => {
                put_u8(&mut b, T_STATS_REPLY);
                put_u64(&mut b, *served);
                put_u64(&mut b, *rows);
                put_u64(&mut b, *errors);
                put_u64(&mut b, *reloads);
                put_u64(&mut b, *total_latency_us);
                put_u64(&mut b, *max_latency_us);
                put_str(&mut b, backend);
                put_u64(&mut b, *d);
            }
            Msg::ServeError { id, message } => {
                put_u8(&mut b, T_SERVE_ERR);
                put_u64(&mut b, *id);
                put_str(&mut b, message);
            }
        }
        b
    }

    pub fn decode(payload: &[u8]) -> Result<Msg, DecodeErr> {
        let mut rd = Rd::new(payload);
        let msg = match rd.u8()? {
            T_HELLO => Msg::Hello { worker: rd.u32()? },
            T_START => Msg::Start {
                fingerprint: rd.u64()?,
                heartbeat_ms: rd.u64()?,
                cfg_toml: rd.str()?,
                ds_name: rd.str()?,
                d: rd.u64()?,
                libsvm: rd.str()?,
                cache_path: rd.str()?,
            },
            T_READY => Msg::Ready { worker: rd.u32()?, fingerprint: rd.u64()? },
            T_DELIVER => Msg::Deliver {
                seq: rd.u64()?,
                block_id: rd.u32()?,
                hops: rd.u64()?,
                w: rd.f32s()?,
                acc: rd.f32s()?,
            },
            T_ADOPT => Msg::Adopt { seq: rd.u64()?, stripes: get_stripes(&mut rd)? },
            T_FWD => Msg::Fwd {
                seq: rd.u64()?,
                visit: rd.u64()?,
                updates: rd.u64()?,
                dropped: rd.bool()?,
                block_id: rd.u32()?,
                dw: Delta::get(&mut rd)?,
                dacc: Delta::get(&mut rd)?,
                stripes: get_stripes(&mut rd)?,
            },
            T_ACK => Msg::Ack { seq: rd.u64()? },
            T_NACK => Msg::Nack { seq: rd.u64()? },
            T_HEARTBEAT => Msg::Heartbeat,
            T_BYE => Msg::Bye,
            T_KILLME => Msg::KillMe,
            T_SHUTDOWN => Msg::Shutdown,
            T_PREDICT => Msg::Predict { id: rd.u64()?, batch: rd.str()? },
            T_SCORES => Msg::Scores { id: rd.u64()?, scores: rd.f64s()? },
            T_RELOAD => Msg::Reload { path: rd.str()? },
            T_STATS => Msg::StatsReq,
            T_STATS_REPLY => Msg::StatsReply {
                served: rd.u64()?,
                rows: rd.u64()?,
                errors: rd.u64()?,
                reloads: rd.u64()?,
                total_latency_us: rd.u64()?,
                max_latency_us: rd.u64()?,
                backend: rd.str()?,
                d: rd.u64()?,
            },
            T_SERVE_ERR => Msg::ServeError { id: rd.u64()?, message: rd.str()? },
            t => return Err(DecodeErr(format!("unknown message tag {t}"))),
        };
        rd.done()?;
        Ok(msg)
    }
}

// ---- config shipping -----------------------------------------------

fn toml_quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Emit the subset of [`TrainConfig`] a worker process needs to
/// rebuild `DsoSetup` (model, optimizer, cluster) as TOML that
/// `TrainConfig::from_toml` round-trips. `f64` values use the `{:?}`
/// shortest-round-trip form, so the worker sees bit-identical
/// hyperparameters and the fingerprint handshake can be strict.
pub fn emit_config(cfg: &TrainConfig) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "[model]");
    let _ = writeln!(s, "loss = {}", toml_quote(cfg.model.loss.name()));
    let _ = writeln!(s, "regularizer = {}", toml_quote(cfg.model.reg.name()));
    let _ = writeln!(s, "lambda = {:?}", cfg.model.lambda);
    let _ = writeln!(s, "[optim]");
    let _ = writeln!(s, "algorithm = {}", toml_quote(cfg.optim.algorithm.name()));
    let _ = writeln!(s, "step = {}", toml_quote(cfg.optim.step.name()));
    let _ = writeln!(s, "eta0 = {:?}", cfg.optim.eta0);
    let _ = writeln!(s, "epochs = {}", cfg.optim.epochs);
    let _ = writeln!(s, "dcd_init = {}", cfg.optim.dcd_init);
    let _ = writeln!(s, "seed = {}", cfg.optim.seed);
    let _ = writeln!(s, "[cluster]");
    let _ = writeln!(s, "machines = {}", cfg.cluster.machines);
    let _ = writeln!(s, "cores = {}", cfg.cluster.cores);
    let _ = writeln!(s, "latency_us = {:?}", cfg.cluster.latency_us);
    let _ = writeln!(s, "bandwidth_mbps = {:?}", cfg.cluster.bandwidth_mbps);
    let _ = writeln!(s, "mode = {}", toml_quote(cfg.cluster.mode.name()));
    let _ = writeln!(s, "updates_per_block = {}", cfg.cluster.updates_per_block);
    let _ = writeln!(s, "tile_iters = {}", cfg.cluster.tile_iters);
    let _ = writeln!(s, "partition = {}", toml_quote(cfg.cluster.partition.name()));
    let _ = writeln!(s, "simd = {}", toml_quote(cfg.cluster.simd.name()));
    let _ = writeln!(s, "heartbeat_ms = {}", cfg.cluster.heartbeat_ms);
    let _ = writeln!(s, "death_timeout_ms = {}", cfg.cluster.death_timeout_ms);
    if !cfg.cluster.faults.is_empty() {
        let _ = writeln!(s, "faults = {}", toml_quote(&cfg.cluster.faults));
    }
    let _ = writeln!(s, "[monitor]");
    let _ = writeln!(s, "every = 0");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_round_trips() {
        let payload = b"hello frame".to_vec();
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, &payload).unwrap();
        assert_eq!(n, buf.len());
        let mut rd = Cursor::new(buf);
        match read_frame(&mut rd).unwrap() {
            FrameIn::Frame(p) => assert_eq!(p, payload),
            other => panic!("expected frame, got {other:?}"),
        }
        // The stream is now empty: a second read is a clean EOF.
        assert!(matches!(read_frame(&mut rd).unwrap(), FrameIn::Eof));
    }

    #[test]
    fn corrupt_payload_is_rejected_not_delivered() {
        let payload = b"checksums matter".to_vec();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        // Flip one payload bit past the header.
        buf[FRAME_HEADER + 3] ^= 0x40;
        let mut rd = Cursor::new(buf);
        match read_frame(&mut rd).unwrap() {
            FrameIn::Corrupt { wire_bytes } => {
                assert_eq!(wire_bytes, FRAME_HEADER + payload.len())
            }
            other => panic!("expected corrupt, got {other:?}"),
        }
    }

    #[test]
    fn garbled_length_prefix_is_corruption() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"x").unwrap();
        buf[3] = 0xff; // length now far above MAX_FRAME
        let mut rd = Cursor::new(buf);
        assert!(matches!(read_frame(&mut rd).unwrap(), FrameIn::Corrupt { .. }));
    }

    #[test]
    fn torn_frame_is_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"truncate me please").unwrap();
        buf.truncate(buf.len() - 5);
        let mut rd = Cursor::new(buf);
        assert!(matches!(read_frame(&mut rd).unwrap(), FrameIn::Eof));
    }

    #[test]
    fn msg_codec_round_trips_every_variant() {
        let weird = f32::from_bits(0x7fc0_1234); // NaN with payload
        let msgs = vec![
            Msg::Hello { worker: 3 },
            Msg::Start {
                fingerprint: 0xdead_beef_cafe_f00d,
                heartbeat_ms: 50,
                cfg_toml: "[model]\nloss = \"hinge\"\n".into(),
                ds_name: "synth".into(),
                d: 60,
                libsvm: "+1 1:0.5 7:-0.25\n-1 2:1\n".into(),
                cache_path: "/tmp/dso-cache/synth.dsoblk".into(),
            },
            Msg::Ready { worker: 3, fingerprint: 42 },
            Msg::Deliver {
                seq: 9,
                block_id: 2,
                hops: 17,
                w: vec![0.0, -0.0, 1.5, weird],
                acc: vec![0.25; 4],
            },
            Msg::Adopt {
                seq: 4,
                stripes: vec![StripeMsg {
                    q: 1,
                    alpha: vec![0.5, -1.0],
                    a_acc: vec![0.0, 2.0],
                }],
            },
            Msg::Fwd {
                seq: 11,
                visit: 6,
                updates: 321,
                dropped: true,
                block_id: 0,
                dw: Delta::Sparse { len: 8, changes: vec![(1, 0.5), (7, weird)] },
                dacc: Delta::Full(vec![1.0, 2.0, 3.0]),
                stripes: vec![StripeMsg { q: 0, alpha: vec![1.0], a_acc: vec![0.5] }],
            },
            Msg::Ack { seq: 7 },
            Msg::Nack { seq: 2 },
            Msg::Heartbeat,
            Msg::Bye,
            Msg::KillMe,
            Msg::Shutdown,
            Msg::Predict { id: 99, batch: "+1 1:0.5 3:-2\n0 2:1.25\n".into() },
            Msg::Scores {
                id: 99,
                scores: vec![0.0, -0.0, 1.5e-300, f64::from_bits(0x7ff8_0000_0000_0042)],
            },
            Msg::Reload { path: "/tmp/retrained-model.txt".into() },
            Msg::StatsReq,
            Msg::StatsReply {
                served: 12,
                rows: 480,
                errors: 1,
                reloads: 2,
                total_latency_us: 3456,
                max_latency_us: 789,
                backend: "avx2".into(),
                d: 60,
            },
            Msg::ServeError { id: 99, message: "line 2: bad value 'x'".into() },
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Msg::decode(&enc).unwrap();
            // Bit-level equality for the float payloads: PartialEq on
            // f32 treats NaN != NaN, so compare the re-encoding.
            assert_eq!(dec.encode(), enc, "round trip changed bytes for {m:?}");
        }
    }

    #[test]
    fn decode_rejects_trailing_bytes_and_bad_tags() {
        let mut enc = Msg::Ack { seq: 1 }.encode();
        enc.push(0);
        assert!(Msg::decode(&enc).is_err(), "trailing byte accepted");
        assert!(Msg::decode(&[200u8, 0, 0]).is_err(), "unknown tag accepted");
        assert!(Msg::decode(&[]).is_err(), "empty payload accepted");
    }

    #[test]
    fn delta_picks_sparse_for_small_changes_and_is_bit_exact() {
        let base: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let mut new = base.clone();
        new[3] = -0.0; // bit change only (base[3] = 1.5 → sign matters anyway)
        new[40] = f32::from_bits(0x7fc0_0042); // NaN payload
        let d = Delta::encode(&base, &new);
        assert!(matches!(d, Delta::Sparse { .. }), "2/64 changes must go sparse");
        let mut applied = base.clone();
        d.apply(&mut applied).unwrap();
        let bits =
            |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&applied), bits(&new), "delta apply not bit-exact");
    }

    #[test]
    fn delta_falls_back_to_full_when_dense_or_resized() {
        let base = vec![0.0f32; 8];
        let new: Vec<f32> = (0..8).map(|i| i as f32 + 1.0).collect();
        assert!(matches!(Delta::encode(&base, &new), Delta::Full(_)));
        // Length mismatch (first send / post-adoption) is always full.
        assert!(matches!(Delta::encode(&[], &new), Delta::Full(_)));
        // Applying full replaces the baseline outright.
        let mut b = vec![9.0f32; 3];
        Delta::Full(new.clone()).apply(&mut b).unwrap();
        assert_eq!(b, new);
        // Sparse onto a wrong-length baseline is rejected.
        let d = Delta::Sparse { len: 8, changes: vec![(0, 1.0)] };
        assert!(d.apply(&mut vec![0.0f32; 4]).is_err());
    }

    #[test]
    fn emitted_config_round_trips_through_from_toml() {
        let mut cfg = TrainConfig::default();
        cfg.optim.algorithm = crate::config::Algorithm::DsoAsync;
        cfg.optim.epochs = 3;
        cfg.optim.eta0 = 0.2;
        cfg.optim.seed = 7;
        cfg.model.lambda = 1e-3;
        cfg.cluster.machines = 4;
        cfg.cluster.cores = 1;
        cfg.cluster.faults = "stall@0.0.1:5".into();
        let text = emit_config(&cfg);
        let back = TrainConfig::from_toml(&text).unwrap();
        assert_eq!(back.model.loss, cfg.model.loss);
        assert_eq!(back.model.lambda.to_bits(), cfg.model.lambda.to_bits());
        assert_eq!(back.optim.algorithm, cfg.optim.algorithm);
        assert_eq!(back.optim.eta0.to_bits(), cfg.optim.eta0.to_bits());
        assert_eq!(back.optim.seed, cfg.optim.seed);
        assert_eq!(back.cluster.machines, cfg.cluster.machines);
        assert_eq!(back.cluster.partition, cfg.cluster.partition);
        assert_eq!(back.cluster.faults, cfg.cluster.faults);
        assert_eq!(back.monitor.every, 0, "workers never self-evaluate");
    }

    #[test]
    fn fnv1a_matches_reference_offsets() {
        // FNV-1a of the empty string is the offset basis.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        // And of "a" (one multiply step) — regression-pins the prime.
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
