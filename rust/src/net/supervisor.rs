//! Multi-process DSO: a supervising coordinator and its worker
//! processes, connected over Unix-domain sockets (DESIGN.md
//! §Transport).
//!
//! Topology is a **star**: every worker dials the coordinator's
//! listener, and the coordinator relays w-block tokens between workers
//! — it is the single place that picks token destinations (NOMAD's
//! uniform routing rule, one seeded RNG), holds the authoritative copy
//! of every token and row stripe, and appends each completed visit to
//! the recorded schedule. That centralization is what makes the
//! recorded schedule a *serialization certificate*: the log order is
//! consistent with both the per-token and the per-stripe orders of the
//! real run, so [`replay_recorded_schedule`] re-executing the entries
//! serially reproduces the reassembled (w, α) bit-for-bit (Lemma 2
//! across the process boundary; pinned by `tests/transport_chaos.rs`).
//!
//! # Protocol
//!
//! Bootstrap: the worker dials with [`connect_with_backoff`], sends
//! `Hello`, receives `Start` (config TOML + libsvm text + the run
//! fingerprint), rebuilds [`DsoSetup`] deterministically, and replies
//! `Ready` with its *independently recomputed* fingerprint — the
//! supervisor refuses the ring on a mismatch, the same contract the
//! checkpoint resume path enforces.
//!
//! Steady state: the supervisor `Deliver`s a token (full state — the
//! baseline the answering delta refers to), the worker sweeps it
//! against every row stripe it owns and returns a `Fwd` whose token is
//! delta-encoded against the delivered baseline with its full updated
//! stripe state piggybacked. The supervisor applies the delta to its
//! authoritative copy, logs the visit, and routes the token onward.
//! Sequenced frames (`Deliver`/`Adopt`/`Fwd`) are retained until acked
//! and resent verbatim after a corrupt frame (`Nack`) or a reconnect,
//! and each side applies a sequence number exactly once, in order — so
//! delta baselines can never skew.
//!
//! # Failure model
//!
//! Worker death is detected at the socket: EOF (or a silent link) is
//! given `death_timeout_ms` of grace for a reconnect (the `partition@`
//! fault exercises exactly this path), after which the supervisor runs
//! the death protocol: reap the child, reassign its row stripes to a
//! surviving worker (`Adopt`), re-deliver its in-flight tokens from
//! the authoritative copies ("state as of the last *logged* sweep" —
//! a visit that died mid-sweep was never logged and leaves no trace),
//! and report a [`WorkerFailure`]. `die@` makes the worker send `Bye`
//! and exit; `kill@` makes it send `KillMe` so the supervisor delivers
//! a real SIGKILL at a deterministic fault-clock coordinate. A hung
//! worker (silent but connected past the death timeout) is SIGKILLed
//! too. When every worker is gone the run ends early with whatever
//! progress exists.
//!
//! Drain: at the visit target the supervisor broadcasts `Shutdown`,
//! keeps applying (and logging) straggler `Fwd`s until every token is
//! parked, then enforces the p-token / p-stripe invariants before
//! reassembly — the same completeness checks as the in-thread ring.
//!
//! Socket I/O here must never `unwrap()`/`expect()` (scripts/ci.sh
//! greps this file): a dying peer is an expected event that feeds the
//! death protocol, not a coordinator panic.

use super::transport::{connect_with_backoff, ConnIn, FrameConn};
use super::wire::{self, Delta, Msg, StripeMsg};
use super::{MsgFault, WorkerFault};
use crate::config::{StepKind, TrainConfig};
use crate::coordinator::async_engine::sweep_stripe_block;
use crate::coordinator::checkpoint;
use crate::coordinator::engine::DsoSetup;
use crate::coordinator::monitor::{EpochObserver, Monitor, TrainResult, WorkerFailure};
use crate::coordinator::updates::StepRule;
use crate::data::{libsvm, Dataset};
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::fmt::Write as _;
use std::io;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---- recorded schedule ---------------------------------------------

const SCHED_MAGIC: &str = "dso-schedule v1";

/// One logged visit: worker `worker` swept w block `block` against the
/// listed row stripes (in sweep order), producing `updates` updates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScheduleEntry {
    pub worker: u32,
    pub block: u32,
    pub updates: u64,
    /// Row-stripe home indices, in the order they were swept.
    pub stripes: Vec<u32>,
}

/// A parsed recorded schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub fingerprint: u64,
    pub p: usize,
    pub entries: Vec<ScheduleEntry>,
    /// Death events in the log (informational; replay needs only the
    /// per-visit stripe lists).
    pub deaths: usize,
}

impl Schedule {
    pub fn parse(text: &str) -> Result<Schedule> {
        let mut lines = text.lines();
        let magic = lines.next().unwrap_or_default();
        anyhow::ensure!(magic == SCHED_MAGIC, "not a recorded schedule (bad magic '{magic}')");
        let mut fingerprint = None;
        let mut p = None;
        let mut entries = Vec::new();
        let mut deaths = 0usize;
        for line in lines {
            let mut f = line.split_whitespace();
            match f.next() {
                None => {}
                Some("fingerprint") => {
                    let v = f.next().ok_or_else(|| anyhow::anyhow!("bare fingerprint line"))?;
                    fingerprint = Some(
                        u64::from_str_radix(v, 16)
                            .map_err(|_| anyhow::anyhow!("bad schedule fingerprint '{v}'"))?,
                    );
                }
                Some("p") => {
                    let v = f.next().ok_or_else(|| anyhow::anyhow!("bare p line"))?;
                    p = Some(v.parse().map_err(|_| anyhow::anyhow!("bad worker count '{v}'"))?);
                }
                Some("visit") => {
                    let mut num = |what: &str| -> Result<u64> {
                        f.next()
                            .ok_or_else(|| anyhow::anyhow!("visit line missing {what}: '{line}'"))?
                            .parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("bad {what} in '{line}'"))
                    };
                    let worker = num("worker")? as u32;
                    let block = num("block")? as u32;
                    let updates = num("updates")?;
                    let stripes: Vec<u32> = f
                        .map(|s| {
                            s.parse::<u32>()
                                .map_err(|_| anyhow::anyhow!("bad stripe id '{s}' in '{line}'"))
                        })
                        .collect::<Result<_>>()?;
                    anyhow::ensure!(!stripes.is_empty(), "visit with no stripes: '{line}'");
                    entries.push(ScheduleEntry { worker, block, updates, stripes });
                }
                Some("death") => deaths += 1,
                Some(k) => anyhow::bail!("unknown schedule record '{k}'"),
            }
        }
        Ok(Schedule {
            fingerprint: fingerprint.ok_or_else(|| anyhow::anyhow!("schedule missing fingerprint"))?,
            p: p.ok_or_else(|| anyhow::anyhow!("schedule missing worker count"))?,
            entries,
            deaths,
        })
    }
}

/// Incremental schedule writer (the supervisor appends as Fwds land).
struct SchedLog {
    path: PathBuf,
    buf: String,
}

impl SchedLog {
    fn create(path: &str, fingerprint: u64, p: usize) -> SchedLog {
        let mut buf = String::new();
        let _ = writeln!(buf, "{SCHED_MAGIC}");
        let _ = writeln!(buf, "fingerprint {fingerprint:016x}");
        let _ = writeln!(buf, "p {p}");
        SchedLog { path: PathBuf::from(path), buf }
    }

    fn visit(&mut self, worker: usize, block: usize, updates: u64, stripes: &[u32]) {
        let _ = write!(self.buf, "visit {worker} {block} {updates}");
        for q in stripes {
            let _ = write!(self.buf, " {q}");
        }
        self.buf.push('\n');
    }

    fn death(&mut self, worker: usize, epoch: usize, iter: usize, stripes: usize) {
        let _ = writeln!(self.buf, "death {worker} {epoch} {iter} {stripes}");
    }

    fn commit(&self) -> Result<()> {
        std::fs::write(&self.path, &self.buf)
            .map_err(|e| anyhow::anyhow!("writing schedule {}: {e}", self.path.display()))?;
        Ok(())
    }
}

// ---- serial replay -------------------------------------------------

/// Result of serially re-executing a recorded schedule.
pub struct Replayed {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub total_updates: u64,
    pub visits: usize,
}

/// Re-execute a recorded schedule serially: same `DsoSetup`, same
/// initial state, entries applied in log order through the shared
/// sweep entry point. Because every visit reads/writes only (token
/// `block`, the listed stripes) and the log order is consistent with
/// each token's and each stripe's own order in the real run, the
/// result is bit-identical to the multi-process run's reassembled
/// (w, α) — Lemma 2, pinned across the process boundary.
pub fn replay_recorded_schedule(
    cfg: &TrainConfig,
    train: &Dataset,
    path: &Path,
) -> Result<Replayed> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading schedule {}: {e}", path.display()))?;
    let sched = Schedule::parse(&text)?;
    let setup = DsoSetup::new(cfg, train);
    let p = setup.p;
    let fp =
        checkpoint::fingerprint(cfg, train.m(), train.d(), train.x.nnz(), p, setup.plan.simd());
    anyhow::ensure!(
        sched.fingerprint == fp,
        "schedule {} was recorded by a different run (fingerprint {:016x}, this \
         configuration {fp:016x}); refusing to replay a foreign schedule",
        path.display(),
        sched.fingerprint,
    );
    anyhow::ensure!(sched.p == p, "schedule has p = {}, this run has p = {p}", sched.p);

    let loss = setup.problem.loss;
    let rule = match cfg.optim.step {
        StepKind::Adaptive => StepRule::Adaptive(cfg.optim.eta0),
        _ => StepRule::AdaGrad(cfg.optim.eta0),
    };
    let mut tokens: Vec<(Vec<f32>, Vec<f32>)> = (0..p)
        .map(|b| {
            let len = setup.omega.col_part.block(b).len();
            (vec![0f32; len], vec![0f32; len])
        })
        .collect();
    let mut stripes: Vec<(Vec<f32>, Vec<f32>)> = (0..p)
        .map(|q| {
            (
                setup
                    .omega
                    .row_part
                    .block(q)
                    .map(|i| loss.alpha_init(train.y[i] as f64) as f32)
                    .collect(),
                vec![0f32; setup.omega.row_part.block_len(q)],
            )
        })
        .collect();

    let mut scratch: Vec<u32> = Vec::new();
    let mut total = 0u64;
    for (i, e) in sched.entries.iter().enumerate() {
        let b = e.block as usize;
        anyhow::ensure!(b < p, "visit {i}: block {b} out of range");
        let (tw, tacc) = match tokens.get_mut(b) {
            Some(t) => t,
            None => anyhow::bail!("visit {i}: block {b} out of range"),
        };
        let mut n = 0u64;
        for &q in &e.stripes {
            let q = q as usize;
            anyhow::ensure!(q < p, "visit {i}: stripe {q} out of range");
            // Split-borrow: stripes[q] is disjoint from tokens[b].
            let (alpha, a_acc) = match stripes.get_mut(q) {
                Some(s) => s,
                None => anyhow::bail!("visit {i}: stripe {q} out of range"),
            };
            n += sweep_stripe_block(&setup, rule, q, b, tw, tacc, alpha, a_acc, &mut scratch)
                as u64;
        }
        // Update counts are deterministic given the state, so a count
        // mismatch localizes a divergence to the exact visit.
        anyhow::ensure!(
            n == e.updates,
            "replay diverged at visit {i} (worker {}, block {b}): swept {n} updates, \
             the run recorded {}",
            e.worker,
            e.updates
        );
        total += n;
    }

    let mut w = vec![0f32; train.d()];
    for (b, (tw, _)) in tokens.iter().enumerate() {
        w[setup.omega.col_part.block(b)].copy_from_slice(tw);
    }
    let mut alpha = vec![0f32; train.m()];
    for (q, (a, _)) in stripes.iter().enumerate() {
        alpha[setup.omega.row_part.block(q)].copy_from_slice(a);
    }
    Ok(Replayed { w, alpha, total_updates: total, visits: sched.entries.len() })
}

// ---- supervisor ----------------------------------------------------

/// Events the listener/reader threads feed the single-threaded relay
/// loop (which alone owns the write halves and all ring state).
enum Ev {
    /// A (re)connection identified itself as `worker`.
    Conn { worker: usize, stream: UnixStream },
    In { worker: usize, msg: Msg },
    /// A frame from `worker` failed its checksum — answer with a Nack.
    Corrupt { worker: usize },
    /// The worker's socket reached EOF (exit, crash, or link fault).
    Gone { worker: usize },
}

fn reader_thread(
    stream: UnixStream,
    tx: Sender<Ev>,
    recv_total: Arc<AtomicU64>,
    hello_timeout: Duration,
) {
    let mut conn = FrameConn::new(stream);
    // The first frame must identify the worker; a stray connection
    // that never says Hello is dropped without an event.
    if conn.set_recv_timeout(Some(hello_timeout)).is_err() {
        return;
    }
    let worker = match conn.recv() {
        Ok(ConnIn::Msg(Msg::Hello { worker })) => worker as usize,
        _ => return,
    };
    let write_half = match conn.try_clone_stream() {
        Ok(s) => s,
        Err(_) => return,
    };
    if conn.set_recv_timeout(None).is_err() {
        return;
    }
    if tx.send(Ev::Conn { worker, stream: write_half }).is_err() {
        return;
    }
    let mut prev = conn.recv_bytes;
    let mut corrupt_streak = 0u32;
    loop {
        let ev = match conn.recv() {
            Ok(ConnIn::Msg(m)) => {
                corrupt_streak = 0;
                Ev::In { worker, msg: m }
            }
            Ok(ConnIn::Corrupt) => {
                corrupt_streak += 1;
                if corrupt_streak > 8 {
                    // Framing is lost (e.g. garbled length prefix);
                    // treat the link as dead rather than nack forever.
                    Ev::Gone { worker }
                } else {
                    Ev::Corrupt { worker }
                }
            }
            Ok(ConnIn::Eof) | Ok(ConnIn::TimedOut) | Err(_) => Ev::Gone { worker },
        };
        recv_total.fetch_add(conn.recv_bytes - prev, Ordering::Relaxed);
        prev = conn.recv_bytes;
        let gone = matches!(ev, Ev::Gone { .. });
        if tx.send(ev).is_err() || gone {
            return;
        }
    }
}

fn listener_thread(
    listener: UnixListener,
    tx: Sender<Ev>,
    recv_total: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    hello_timeout: Duration,
) {
    let _ = listener.set_nonblocking(true);
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                // The accepted socket may inherit the listener's
                // non-blocking flag; readers want blocking reads.
                let _ = stream.set_nonblocking(false);
                let tx = tx.clone();
                let rt = Arc::clone(&recv_total);
                std::thread::spawn(move || reader_thread(stream, tx, rt, hello_timeout));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Authoritative copy of one circulating w block.
struct TokenSt {
    w: Vec<f32>,
    acc: Vec<f32>,
    hops: u64,
    /// Worker currently holding the token; `None` = parked.
    holder: Option<usize>,
}

/// Authoritative copy of one row stripe (state as of the owner's last
/// *logged* sweep — the piggyback on every `Fwd` keeps this current).
struct StripeSt {
    alpha: Vec<f32>,
    a_acc: Vec<f32>,
    owner: usize,
}

/// Supervisor-side per-worker state. The write half of the connection
/// lives here (readers run on their own threads); `conn` survives
/// reconnects via `replace_stream`, keeping unacked frames and byte
/// counters across link faults.
struct Peer {
    conn: Option<FrameConn>,
    child: Option<Child>,
    alive: bool,
    ready: bool,
    last_seen: Instant,
    /// Set at EOF; a reconnect clears it, the death timeout expires it.
    gone_since: Option<Instant>,
    /// Next coordinator→worker sequence number.
    next_seq: u64,
    /// Next expected worker→coordinator sequence number.
    expect: u64,
    /// Completed (logged) visits — the worker-local fault clock, as
    /// observed from the supervisor side.
    visits: u64,
}

impl Peer {
    fn send(&mut self, msg: &Msg) {
        // Write errors are survivable: the death timeout or reconnect
        // protocol picks the peer up, and tracked frames stay queued.
        if let Some(c) = self.conn.as_mut() {
            let _ = c.send(msg);
        }
    }

    fn send_tracked(&mut self, msg: &Msg) {
        let seq = self.next_seq;
        self.next_seq += 1;
        if let Some(c) = self.conn.as_mut() {
            let _ = c.send_tracked(seq, msg);
        }
    }
}

/// Uniformly random live worker, preferring one other than `from`
/// (NOMAD's routing rule); `from` itself only as the sole survivor.
fn pick_live(rng: &mut Xoshiro256, peers: &[Peer], from: usize) -> Option<usize> {
    let p = peers.len();
    for _ in 0..4 * p {
        let c = rng.gen_index(p);
        if c != from && peers[c].alive {
            return Some(c);
        }
    }
    let start = rng.gen_index(p);
    let mut fallback = None;
    for k in 0..p {
        let c = (start + k) % p;
        if peers[c].alive {
            if c != from {
                return Some(c);
            }
            fallback = Some(c);
        }
    }
    fallback
}

fn deliver(peers: &mut [Peer], tokens: &mut [TokenSt], block: usize, to: usize) {
    let t = &mut tokens[block];
    t.holder = Some(to);
    // The sequence number is part of the encoded frame, so it must be
    // read before encoding (send_tracked consumes the same counter).
    let msg = Msg::Deliver {
        seq: peers[to].next_seq,
        block_id: block as u32,
        hops: t.hops,
        w: t.w.clone(),
        acc: t.acc.clone(),
    };
    peers[to].send_tracked(&msg);
}

/// Everything the run produced besides the authoritative state.
struct RingOutcome {
    updates: u64,
    visits: u64,
    dropped: u64,
    failures: Vec<WorkerFailure>,
    wait_s: f64,
}

struct Ring<'a> {
    cfg: &'a TrainConfig,
    fp: u64,
    /// Out-of-core handoff: nonempty = path of the packed `.dsoblk`
    /// cache workers mmap instead of receiving the shard as libsvm
    /// text over the socket.
    cache_file: String,
    /// The config TOML workers bootstrap from, with the supervisor's
    /// resolved SIMD backend pinned as a forced kind. Under measured
    /// `auto`, each worker process would otherwise run its own
    /// micro-autotune and a borderline host could crown a different
    /// winner than the supervisor — failing the fingerprint handshake.
    start_toml: String,
    p: usize,
    target: u64,
    death_timeout: Duration,
    rng: Xoshiro256,
    sched: Option<SchedLog>,
    out: RingOutcome,
    stop: bool,
}

impl Ring<'_> {
    /// The death protocol: reap the child, reassign stripes to a
    /// survivor, re-deliver held tokens from the authoritative copies,
    /// record the failure. Safe to call twice (second call no-ops).
    fn death(
        &mut self,
        peers: &mut [Peer],
        tokens: &mut [TokenSt],
        stripes: &mut [StripeSt],
        worker: usize,
        reason: &str,
    ) {
        if !peers[worker].alive {
            return;
        }
        peers[worker].alive = false;
        if let Some(ch) = peers[worker].child.as_mut() {
            let _ = ch.kill();
            let _ = ch.wait();
        }
        let wv = peers[worker].visits as usize;
        let (epoch, iter) = (wv / self.p, wv % self.p);
        let owned: Vec<usize> =
            (0..self.p).filter(|&q| stripes[q].owner == worker).collect();
        self.out.failures.push(WorkerFailure {
            worker,
            epoch,
            iter,
            reason: reason.to_string(),
            stripes_reassigned: owned.len(),
        });
        if let Some(s) = self.sched.as_mut() {
            s.death(worker, epoch, iter, owned.len());
        }
        let survivors = peers.iter().filter(|pr| pr.alive).count();
        if survivors == 0 {
            // Nobody left to adopt or compute: end the run, parking
            // everything from the authoritative copies.
            self.stop = true;
            for t in tokens.iter_mut() {
                if t.holder == Some(worker) {
                    t.holder = None;
                }
            }
            return;
        }
        // One random survivor adopts every orphaned stripe (mirrors
        // the in-thread ring's "first survivor through takes all").
        if !owned.is_empty() {
            if let Some(adopter) = pick_live(&mut self.rng, peers, worker) {
                let smsgs: Vec<StripeMsg> = owned
                    .iter()
                    .map(|&q| StripeMsg {
                        q: q as u32,
                        alpha: stripes[q].alpha.clone(),
                        a_acc: stripes[q].a_acc.clone(),
                    })
                    .collect();
                for &q in &owned {
                    stripes[q].owner = adopter;
                }
                let seq = peers[adopter].next_seq;
                peers[adopter].send_tracked(&Msg::Adopt { seq, stripes: smsgs });
            }
        }
        // Tokens the dead worker held re-enter the ring from the state
        // of their last completed sweep (a mid-sweep visit never
        // logged, so authoritative == last logged).
        for b in 0..tokens.len() {
            if tokens[b].holder != Some(worker) {
                continue;
            }
            if self.stop {
                tokens[b].holder = None;
            } else if let Some(dst) = pick_live(&mut self.rng, peers, worker) {
                deliver(peers, tokens, b, dst);
            } else {
                tokens[b].holder = None;
            }
        }
    }

    fn begin_drain(&mut self, peers: &mut [Peer]) {
        self.stop = true;
        for pr in peers.iter_mut() {
            if pr.alive {
                pr.send(&Msg::Shutdown);
            }
        }
    }

    /// Process one completed visit (a deduplicated `Fwd`).
    #[allow(clippy::too_many_arguments)]
    fn apply_fwd(
        &mut self,
        peers: &mut [Peer],
        tokens: &mut [TokenSt],
        stripes: &mut [StripeSt],
        worker: usize,
        block_id: u32,
        updates: u64,
        dropped: bool,
        dw: &Delta,
        dacc: &Delta,
        smsgs: &[StripeMsg],
    ) -> Result<()> {
        let b = block_id as usize;
        anyhow::ensure!(b < tokens.len(), "Fwd for unknown block {b}");
        anyhow::ensure!(
            tokens[b].holder == Some(worker),
            "Fwd for block {b} from worker {worker}, but the token is at {:?} — \
             sequencing invariant broken",
            tokens[b].holder
        );
        dw.apply(&mut tokens[b].w).map_err(|e| anyhow::anyhow!("block {b} w delta: {e}"))?;
        dacc.apply(&mut tokens[b].acc)
            .map_err(|e| anyhow::anyhow!("block {b} acc delta: {e}"))?;
        tokens[b].hops += 1;
        let mut sids: Vec<u32> = Vec::with_capacity(smsgs.len());
        for sm in smsgs {
            let q = sm.q as usize;
            anyhow::ensure!(q < stripes.len(), "Fwd carries unknown stripe {q}");
            anyhow::ensure!(
                stripes[q].alpha.len() == sm.alpha.len()
                    && stripes[q].a_acc.len() == sm.a_acc.len(),
                "stripe {q} state has wrong length on the wire"
            );
            stripes[q].alpha.copy_from_slice(&sm.alpha);
            stripes[q].a_acc.copy_from_slice(&sm.a_acc);
            sids.push(sm.q);
        }
        self.out.updates += updates;
        if dropped {
            self.out.dropped += 1;
        }
        peers[worker].visits += 1;
        self.out.visits += 1;
        if let Some(s) = self.sched.as_mut() {
            s.visit(worker, b, updates, &sids);
        }
        if !self.stop && self.out.visits >= self.target {
            self.begin_drain(peers);
        }
        if self.stop {
            tokens[b].holder = None;
        } else if let Some(dst) = pick_live(&mut self.rng, peers, worker) {
            deliver(peers, tokens, b, dst);
        } else {
            tokens[b].holder = None;
        }
        Ok(())
    }

    /// Handle a (re)connection that identified itself.
    fn on_conn(&mut self, peers: &mut [Peer], train: &Dataset, worker: usize, stream: UnixStream) {
        let pr = &mut peers[worker];
        if !pr.alive {
            // Declared dead (e.g. a partition that outlived the
            // timeout); its state is already reassigned — refuse.
            return;
        }
        pr.last_seen = Instant::now();
        pr.gone_since = None;
        match pr.conn.as_mut() {
            Some(c) => {
                // Reconnect after a link fault: same counters, same
                // unacked queue — resend verbatim, dedup on the far
                // side keeps delta baselines exact.
                c.replace_stream(stream);
                let _ = c.resend_all();
            }
            None => pr.conn = Some(FrameConn::new(stream)),
        }
        if !pr.ready {
            // With a packed cache on disk, hand the worker its path
            // instead of serializing the whole shard into the frame —
            // the dataset never crosses the socket.
            let libsvm =
                if self.cache_file.is_empty() { libsvm::emit(train) } else { String::new() };
            pr.send(&Msg::Start {
                fingerprint: self.fp,
                heartbeat_ms: self.cfg.cluster.heartbeat_ms,
                cfg_toml: self.start_toml.clone(),
                ds_name: train.name.clone(),
                d: train.d() as u64,
                libsvm,
                cache_path: self.cache_file.clone(),
            });
        } else if self.stop {
            pr.send(&Msg::Shutdown);
        }
    }

    /// Expire death timers: a disconnected worker past its grace, or a
    /// connected-but-silent (hung) worker, dies here.
    fn check_timeouts(
        &mut self,
        peers: &mut [Peer],
        tokens: &mut [TokenSt],
        stripes: &mut [StripeSt],
    ) {
        for w in 0..peers.len() {
            if !peers[w].alive {
                continue;
            }
            if let Some(gs) = peers[w].gone_since {
                if gs.elapsed() > self.death_timeout {
                    self.death(peers, tokens, stripes, w, "connection lost");
                }
            } else if peers[w].conn.is_some()
                && peers[w].last_seen.elapsed() > self.death_timeout
            {
                // Connected but silent past every heartbeat: hung.
                // SIGKILL closes its socket; death() reaps it.
                self.death(peers, tokens, stripes, w, "unresponsive (killed)");
            }
        }
    }
}

fn resolve_worker_bin(cfg: &TrainConfig) -> Result<PathBuf> {
    if !cfg.cluster.worker_bin.is_empty() {
        return Ok(PathBuf::from(&cfg.cluster.worker_bin));
    }
    if let Some(v) = std::env::var_os("DSO_WORKER_BIN") {
        if !v.is_empty() {
            return Ok(PathBuf::from(v));
        }
    }
    std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving worker binary (current_exe): {e}"))
}

fn ring_socket_path() -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("dso-ring-{}-{n}.sock", std::process::id()))
}

/// Train with multi-process DSO (`--mode dso-proc`): the asynchronous
/// ring with one OS process per worker over Unix-domain sockets. The
/// in-thread ring (`dso-async` + scalar mode) is the fast path and the
/// differential oracle; this is the deployment-shaped path with real
/// process death, reconnects, and a recorded schedule.
pub fn train_dso_proc_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    anyhow::ensure!(
        matches!(cfg.optim.step, StepKind::AdaGrad | StepKind::Adaptive),
        "async DSO supports the accumulator rules (adagrad, adaptive — \
         state travels with blocks); epoch-level η_t schedules need a \
         global clock, which async lacks"
    );
    anyhow::ensure!(
        cfg.cluster.updates_per_block == 0,
        "async DSO sweeps whole blocks: the deterministic updates_per_block \
         sampling stream is defined by the synchronous (epoch, worker, \
         inner-iteration) schedule, which async lacks; set \
         cluster.updates_per_block = 0 or use algorithm = \"dso\""
    );
    anyhow::ensure!(
        cfg.cluster.heartbeat_ms > 0 && cfg.cluster.death_timeout_ms > cfg.cluster.heartbeat_ms,
        "dso-proc needs heartbeat_ms > 0 and death_timeout_ms > heartbeat_ms \
         (death detection is timeout-based)"
    );
    let setup = DsoSetup::with_cache(cfg, train)?;
    let p = setup.p;
    let loss = setup.problem.loss;
    let fp =
        checkpoint::fingerprint(cfg, train.m(), train.d(), train.x.nnz(), p, setup.plan.simd());
    // Workers get the cache path (and no embedded shard) whenever a
    // packed file exists for this run — `with_cache` just built or
    // validated it for Build/Use/Auto.
    let cache_file = if cfg.cluster.cache != crate::config::CacheMode::Off
        && !cfg.cluster.cache_dir.is_empty()
    {
        let path = crate::data::cache::cache_path(
            std::path::Path::new(&cfg.cluster.cache_dir),
            &train.name,
        );
        if path.exists() { path.to_string_lossy().into_owned() } else { String::new() }
    } else {
        String::new()
    };
    let death_timeout = Duration::from_millis(cfg.cluster.death_timeout_ms);
    let heartbeat = Duration::from_millis(cfg.cluster.heartbeat_ms);

    let mut tokens: Vec<TokenSt> = (0..p)
        .map(|b| {
            let len = setup.omega.col_part.block(b).len();
            TokenSt { w: vec![0f32; len], acc: vec![0f32; len], hops: 0, holder: None }
        })
        .collect();
    let mut stripes: Vec<StripeSt> = (0..p)
        .map(|q| StripeSt {
            alpha: setup
                .omega
                .row_part
                .block(q)
                .map(|i| loss.alpha_init(train.y[i] as f64) as f32)
                .collect(),
            a_acc: vec![0f32; setup.omega.row_part.block_len(q)],
            owner: q,
        })
        .collect();

    let sock_path = ring_socket_path();
    let _ = std::fs::remove_file(&sock_path);
    let listener = UnixListener::bind(&sock_path)
        .map_err(|e| anyhow::anyhow!("binding ring socket {}: {e}", sock_path.display()))?;
    let (tx, rx) = std::sync::mpsc::channel();
    let stop_accept = Arc::new(AtomicBool::new(false));
    let recv_total = Arc::new(AtomicU64::new(0));
    let listener_h = {
        let tx = tx.clone();
        let rt = Arc::clone(&recv_total);
        let stop = Arc::clone(&stop_accept);
        std::thread::spawn(move || listener_thread(listener, tx, rt, stop, death_timeout))
    };
    drop(tx);

    let bin = resolve_worker_bin(cfg)?;
    let now = Instant::now();
    let mut peers: Vec<Peer> = Vec::with_capacity(p);
    let mut spawn_err = None;
    for q in 0..p {
        let child = Command::new(&bin)
            .arg("__dso-worker")
            .arg("--socket")
            .arg(&sock_path)
            .arg("--worker")
            .arg(q.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn();
        match child {
            Ok(c) => peers.push(Peer {
                conn: None,
                child: Some(c),
                alive: true,
                ready: false,
                last_seen: now,
                gone_since: None,
                next_seq: 0,
                expect: 0,
                visits: 0,
            }),
            Err(e) => {
                spawn_err =
                    Some(anyhow::anyhow!("spawning worker {q} ({}): {e}", bin.display()));
                break;
            }
        }
    }

    // Workers inherit the supervisor's backend verdict as a *forced*
    // kind: a measured `auto` winner must not be re-measured per
    // process (the fingerprint covers the backend name). Workers
    // validate the pinned kind like any explicit request, so a
    // heterogeneous host that can't run it refuses loudly.
    let start_toml = {
        let mut pinned = cfg.clone();
        pinned.cluster.simd = setup.plan.simd().as_kind();
        wire::emit_config(&pinned)
    };
    let wall = Stopwatch::new();
    let mut ring = Ring {
        cfg,
        fp,
        cache_file,
        start_toml,
        p,
        target: (cfg.optim.epochs as u64) * (p as u64) * (p as u64),
        death_timeout,
        rng: Xoshiro256::new(cfg.optim.seed ^ 0xD150_50C7),
        sched: if cfg.cluster.sched_out.is_empty() {
            None
        } else {
            Some(SchedLog::create(&cfg.cluster.sched_out, fp, p))
        },
        out: RingOutcome {
            updates: 0,
            visits: 0,
            dropped: 0,
            failures: Vec::new(),
            wait_s: 0.0,
        },
        stop: false,
    };

    let outcome = match spawn_err {
        Some(e) => Err(e),
        None => run_ring(&mut ring, &mut peers, &mut tokens, &mut stripes, train, &rx, heartbeat),
    };

    // Teardown happens on every path, including errors: stop the
    // listener, reap every child, remove the socket file. Sent-byte
    // counters are harvested here, before the write halves close.
    stop_accept.store(true, Ordering::Release);
    let mut sent_total = 0u64;
    for pr in peers.iter_mut() {
        if let Some(ch) = pr.child.as_mut() {
            let _ = ch.kill();
            let _ = ch.wait();
        }
        if let Some(c) = pr.conn.take() {
            sent_total += c.sent_bytes; // closing the write half EOFs the reader
        }
    }
    let _ = listener_h.join();
    let _ = std::fs::remove_file(&sock_path);
    outcome?;
    if let Some(s) = ring.sched.as_ref() {
        s.commit()?;
    }

    // The drain invariants: every block parked exactly once, every row
    // stripe accounted for exactly once — deaths notwithstanding.
    let parked = tokens.iter().filter(|t| t.holder.is_none()).count();
    anyhow::ensure!(parked == p, "lost blocks: {parked} of {p} parked after drain");
    anyhow::ensure!(stripes.len() == p, "lost row stripes: {} of {p}", stripes.len());
    let mut w = vec![0f32; train.d()];
    for (b, t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            t.w.len() == setup.omega.col_part.block_len(b),
            "block {b} has wrong length after drain"
        );
        w[setup.omega.col_part.block(b)].copy_from_slice(&t.w);
    }
    let mut alpha = vec![0f32; train.m()];
    for (q, s) in stripes.iter().enumerate() {
        anyhow::ensure!(
            s.alpha.len() == setup.omega.row_part.block_len(q),
            "stripe {q} has wrong length after drain"
        );
        alpha[setup.omega.row_part.block(q)].copy_from_slice(&s.alpha);
    }

    let mut monitor = Monitor::observed(0, obs);
    for f in &ring.out.failures {
        monitor.record_failure(f);
    }
    monitor.set_wait_secs(ring.out.wait_s);
    let comm_bytes = recv_total.load(Ordering::Relaxed) + sent_total;
    let updates = ring.out.updates;
    // Real transport: virtual time IS wall time (no simulated costing).
    let wall_s = wall.elapsed_secs();
    let final_primal = setup.problem.primal(train, &w);
    let final_gap = final_primal - setup.problem.dual(train, &alpha);
    monitor.record_saddle(
        &setup.problem,
        train,
        test,
        &w,
        &alpha,
        cfg.optim.epochs,
        wall_s,
        wall_s,
        updates,
        comm_bytes,
    );
    Ok(TrainResult {
        algorithm: "dso-proc".into(),
        w,
        alpha,
        history: monitor.history,
        final_primal,
        final_gap,
        total_updates: updates,
        total_virtual_s: wall_s,
        total_wall_s: wall_s,
        comm_bytes,
        failures: ring.out.failures.clone(),
    })
}

/// The supervisor's event loop: handshake, initial delivery, relay
/// until the visit target, drain.
fn run_ring(
    ring: &mut Ring<'_>,
    peers: &mut [Peer],
    tokens: &mut [TokenSt],
    stripes: &mut [StripeSt],
    train: &Dataset,
    rx: &Receiver<Ev>,
    heartbeat: Duration,
) -> Result<()> {
    let p = ring.p;
    let tick = (heartbeat / 2).max(Duration::from_millis(5));

    // Phase 1: handshake — every worker connected and fingerprint-
    // verified before the first token moves.
    let start_deadline = Instant::now() + Duration::from_secs(10).max(4 * ring.death_timeout);
    while peers.iter().any(|pr| !pr.ready) {
        anyhow::ensure!(
            Instant::now() < start_deadline,
            "worker handshake timed out ({} of {p} ready)",
            peers.iter().filter(|pr| pr.ready).count()
        );
        // A child that exits before Ready never joined the ring —
        // nothing to degrade, so that is a hard startup error.
        for (q, pr) in peers.iter_mut().enumerate() {
            if pr.ready {
                continue;
            }
            if let Some(ch) = pr.child.as_mut() {
                if let Ok(Some(status)) = ch.try_wait() {
                    anyhow::bail!("worker {q} exited during handshake ({status})");
                }
            }
        }
        match rx.recv_timeout(tick) {
            Ok(Ev::Conn { worker, stream }) if worker < p => {
                ring.on_conn(peers, train, worker, stream);
            }
            Ok(Ev::In { worker, msg }) if worker < p => {
                peers[worker].last_seen = Instant::now();
                peers[worker].gone_since = None;
                if let Msg::Ready { worker: w2, fingerprint } = msg {
                    anyhow::ensure!(w2 as usize == worker, "Ready with mismatched worker id");
                    anyhow::ensure!(
                        fingerprint == ring.fp,
                        "worker {worker} rebuilt a different optimization (its fingerprint \
                         {fingerprint:016x}, coordinator {:016x}); refusing to start the ring",
                        ring.fp
                    );
                    peers[worker].ready = true;
                }
            }
            Ok(Ev::Corrupt { worker }) if worker < p => {
                let seq = peers[worker].expect;
                peers[worker].send(&Msg::Nack { seq });
            }
            Ok(Ev::Gone { worker }) if worker < p => {
                peers[worker].gone_since = Some(Instant::now());
            }
            Ok(_) => {}  // out-of-range worker id: stray connection
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("listener thread died during handshake")
            }
        }
    }

    // Phase 2: initial delivery — worker q starts on its own block.
    for q in 0..p {
        deliver(peers, tokens, q, q);
    }

    // Phase 3: relay until the target, then drain stragglers.
    let mut drain_deadline: Option<Instant> = None;
    loop {
        if ring.stop {
            if tokens.iter().all(|t| t.holder.is_none()) {
                break;
            }
            let dl =
                *drain_deadline.get_or_insert_with(|| Instant::now() + 3 * ring.death_timeout);
            anyhow::ensure!(
                Instant::now() < dl,
                "drain stalled: {} of {p} tokens still in flight",
                tokens.iter().filter(|t| t.holder.is_some()).count()
            );
        }
        let t0 = Instant::now();
        match rx.recv_timeout(tick) {
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                ring.out.wait_s += t0.elapsed().as_secs_f64();
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("listener thread died mid-run")
            }
            Ok(Ev::Conn { worker, stream }) if worker < p => {
                ring.on_conn(peers, train, worker, stream);
            }
            Ok(Ev::Corrupt { worker }) if worker < p => {
                peers[worker].last_seen = Instant::now();
                let seq = peers[worker].expect;
                peers[worker].send(&Msg::Nack { seq });
            }
            Ok(Ev::Gone { worker }) if worker < p => {
                let holds = tokens.iter().any(|t| t.holder == Some(worker));
                if ring.stop && !holds {
                    // Clean exit during drain: everything it held is
                    // already parked — not a failure.
                    peers[worker].alive = false;
                    if let Some(ch) = peers[worker].child.as_mut() {
                        let _ = ch.wait();
                    }
                } else {
                    // Crash or link fault: grace period for reconnect.
                    peers[worker].gone_since = Some(Instant::now());
                }
            }
            Ok(Ev::In { worker, msg }) if worker < p => {
                peers[worker].last_seen = Instant::now();
                // A message proves the link is back: an out-of-order
                // Gone from the pre-reconnect reader must not leave a
                // stale death timer running on a live peer.
                peers[worker].gone_since = None;
                if !peers[worker].alive {
                    // Stale frames from a worker already declared dead
                    // (its state was re-routed from the authoritative
                    // copies); applying them would double-count.
                    continue;
                }
                match msg {
                    Msg::Fwd { seq, updates, dropped, block_id, dw, dacc, stripes: sm, .. } => {
                        if seq != peers[worker].expect {
                            if seq < peers[worker].expect {
                                peers[worker].send(&Msg::Ack { seq });
                            }
                            // A gap means a corrupt frame was skipped;
                            // the Nack already requested a resend.
                            continue;
                        }
                        peers[worker].expect += 1;
                        peers[worker].send(&Msg::Ack { seq });
                        ring.apply_fwd(
                            peers, tokens, stripes, worker, block_id, updates, dropped, &dw,
                            &dacc, &sm,
                        )?;
                    }
                    Msg::Ack { seq } => {
                        if let Some(c) = peers[worker].conn.as_mut() {
                            c.ack(seq);
                        }
                    }
                    Msg::Nack { seq } => {
                        if let Some(c) = peers[worker].conn.as_mut() {
                            let _ = c.resend_from(seq);
                        }
                    }
                    Msg::Heartbeat => {}
                    Msg::Bye => {
                        ring.death(peers, tokens, stripes, worker, "injected death");
                    }
                    Msg::KillMe => {
                        // The worker reached a kill@ coordinate on its
                        // own visit clock; the SIGKILL itself comes
                        // from here (death() delivers it).
                        ring.death(peers, tokens, stripes, worker, "injected kill (SIGKILL)");
                    }
                    _ => {}
                }
            }
            Ok(_) => {}
        }
        ring.check_timeouts(peers, tokens, stripes);
        if !ring.stop && peers.iter().all(|pr| !pr.alive) {
            ring.stop = true;
        }
    }
    Ok(())
}

// ---- worker process ------------------------------------------------

/// Entry point for the hidden `__dso-worker` subcommand: dial the
/// supervisor, rebuild the setup from the `Start` payload, then sweep
/// tokens until `Shutdown` (or an injected fault ends us first).
/// Everything a worker knows arrives over the socket — it reads no
/// files and samples no RNG, which is what keeps a visit a pure
/// function of (token, stripes) and the recorded schedule replayable.
pub fn worker_main(socket: &Path, worker: usize) -> Result<()> {
    let dial_deadline = Duration::from_secs(10);
    let stream = connect_with_backoff(socket, dial_deadline)
        .map_err(|e| anyhow::anyhow!("worker {worker}: dialing {}: {e}", socket.display()))?;
    let mut conn = FrameConn::new(stream);
    conn.send(&Msg::Hello { worker: worker as u32 })?;

    // Await Start (bounded by the supervisor's handshake deadline on
    // the other side; locally, by EOF if the supervisor aborts).
    let start = loop {
        match conn.recv()? {
            ConnIn::Msg(m @ Msg::Start { .. }) => break m,
            ConnIn::Msg(_) | ConnIn::TimedOut => {}
            ConnIn::Corrupt => conn.send(&Msg::Nack { seq: 0 })?,
            ConnIn::Eof => anyhow::bail!("worker {worker}: supervisor hung up before Start"),
        }
    };
    let Msg::Start { fingerprint, heartbeat_ms, cfg_toml, ds_name, d, libsvm: ls, cache_path } =
        start
    else {
        unreachable!("loop above only breaks on Start");
    };
    let cfg = TrainConfig::from_toml(&cfg_toml).map_err(anyhow::Error::msg)?;
    // Out-of-core handoff: a nonempty cache path replaces the embedded
    // libsvm shard — the worker mmaps the same fingerprinted `.dsoblk`
    // the supervisor packed/validated, demand-paging the block payload
    // instead of re-parsing and re-packing text. The fingerprint check
    // below still runs on the worker's own recomputation, so a cache
    // swapped underneath the handshake is refused the same way a
    // foreign worker is.
    let (setup, y, nnz) = if cache_path.is_empty() {
        let train = libsvm::parse(&ds_name, &ls, d as usize)?;
        let nnz = train.x.nnz();
        let y = train.y.clone();
        (DsoSetup::new(&cfg, &train), y, nnz)
    } else {
        let path = Path::new(&cache_path);
        let opened = crate::data::cache::open(path)?;
        let pw = cfg.workers().min(opened.m).min(opened.d).max(1);
        let simd = crate::simd::resolve(cfg.cluster.simd);
        let fpc = checkpoint::fingerprint(&cfg, opened.m, opened.d, opened.nnz, pw, simd);
        opened.require_fingerprint(fpc, path)?;
        let nnz = opened.nnz;
        let y = opened.y.clone();
        (DsoSetup::from_cache(&cfg, opened), y, nnz)
    };
    anyhow::ensure!(worker < setup.p, "worker id {worker} out of range (p = {})", setup.p);
    let mut fpw = checkpoint::fingerprint(
        &cfg,
        setup.omega.row_part.n(),
        setup.omega.col_part.n(),
        nnz,
        setup.p,
        setup.plan.simd(),
    );
    // Chaos hook for the refusal test: skew this worker's fingerprint
    // so the supervisor must reject the handshake.
    if std::env::var_os("DSO_PROC_FINGERPRINT_SKEW").is_some() {
        fpw ^= 0xBAD;
    }
    conn.send(&Msg::Ready { worker: worker as u32, fingerprint: fpw })?;
    let _ = fingerprint; // the supervisor, not the worker, arbitrates

    let rule = match cfg.optim.step {
        StepKind::Adaptive => StepRule::Adaptive(cfg.optim.eta0),
        _ => StepRule::AdaGrad(cfg.optim.eta0),
    };
    let loss = setup.problem.loss;
    let p = setup.p as u64;
    // Own row stripe, derived deterministically — identical to the
    // supervisor's authoritative initialization.
    struct WStripe {
        q: usize,
        alpha: Vec<f32>,
        a_acc: Vec<f32>,
    }
    let mut stripes = vec![WStripe {
        q: worker,
        alpha: setup
            .omega
            .row_part
            .block(worker)
            .map(|i| loss.alpha_init(y[i] as f64) as f32)
            .collect(),
        a_acc: vec![0f32; setup.omega.row_part.block_len(worker)],
    }];
    let mut scratch: Vec<u32> = Vec::new();
    let heartbeat = Duration::from_millis(heartbeat_ms.max(1));
    conn.set_recv_timeout(Some(heartbeat))?;
    let mut v: u64 = 0; // worker-local visit clock (fault coordinates)
    let mut expect: u64 = 0; // next expected supervisor seq
    let mut my_seq: u64 = 0; // next Fwd seq
    let mut corrupt_streak = 0u32;
    loop {
        let m = match conn.recv() {
            Ok(ConnIn::TimedOut) => {
                let _ = conn.send(&Msg::Heartbeat);
                continue;
            }
            Ok(ConnIn::Eof) => return Ok(()), // run over (or supervisor died)
            Ok(ConnIn::Corrupt) => {
                corrupt_streak += 1;
                anyhow::ensure!(
                    corrupt_streak <= 8,
                    "worker {worker}: link lost framing (persistent corruption)"
                );
                let _ = conn.send(&Msg::Nack { seq: expect });
                continue;
            }
            Ok(ConnIn::Msg(m)) => {
                corrupt_streak = 0;
                m
            }
            Err(e) => anyhow::bail!("worker {worker}: socket error: {e}"),
        };
        match m {
            Msg::Shutdown => return Ok(()),
            Msg::Ack { seq } => conn.ack(seq),
            Msg::Nack { seq } => {
                let _ = conn.resend_from(seq);
            }
            Msg::Adopt { seq, stripes: smsgs } => {
                if seq != expect {
                    if seq < expect {
                        let _ = conn.send(&Msg::Ack { seq });
                    } else {
                        let _ = conn.send(&Msg::Nack { seq: expect });
                    }
                    continue;
                }
                expect += 1;
                let _ = conn.send(&Msg::Ack { seq });
                for sm in smsgs {
                    stripes.push(WStripe {
                        q: sm.q as usize,
                        alpha: sm.alpha,
                        a_acc: sm.a_acc,
                    });
                }
            }
            Msg::Deliver { seq, block_id, hops: _, w, acc } => {
                if seq != expect {
                    if seq < expect {
                        let _ = conn.send(&Msg::Ack { seq });
                    } else {
                        let _ = conn.send(&Msg::Nack { seq: expect });
                    }
                    continue;
                }
                expect += 1;
                let _ = conn.send(&Msg::Ack { seq });
                // Out-of-core: page in the delivered block's payload
                // for every stripe this worker owns before the sweep.
                for s in stripes.iter() {
                    setup.prefetch(s.q, block_id as usize);
                }
                // Injected faults fire at this worker-local visit
                // coordinate, before the sweep — a killed visit is
                // never logged.
                let (fe, fi) = ((v / p) as usize, (v % p) as usize);
                match setup.faults.worker_fault(worker, fe, fi) {
                    Some(WorkerFault::Kill) => {
                        // Ask the parent for a real SIGKILL (keeps the
                        // fault clock deterministic — a self-abort
                        // could race frames still in flight).
                        let _ = conn.send(&Msg::KillMe);
                        loop {
                            std::thread::sleep(Duration::from_secs(3600));
                        }
                    }
                    Some(WorkerFault::Die) => {
                        let _ = conn.send(&Msg::Bye);
                        return Ok(());
                    }
                    Some(WorkerFault::Partition { millis }) => {
                        // Link fault: sever, wait, reconnect with
                        // backoff, re-identify, resend unacked Fwds.
                        if let Ok(s) = conn.try_clone_stream() {
                            let _ = s.shutdown(std::net::Shutdown::Both);
                        }
                        std::thread::sleep(Duration::from_millis(millis));
                        let s = connect_with_backoff(socket, dial_deadline).map_err(|e| {
                            anyhow::anyhow!("worker {worker}: reconnect failed: {e}")
                        })?;
                        conn.replace_stream(s);
                        conn.set_recv_timeout(Some(heartbeat))?;
                        conn.send(&Msg::Hello { worker: worker as u32 })?;
                        let _ = conn.resend_all();
                    }
                    Some(WorkerFault::Stall { millis }) => {
                        std::thread::sleep(Duration::from_millis(millis));
                    }
                    None => {}
                }
                // Sweep on a working copy; the delivered arrays stay
                // pristine as the delta baseline.
                let mut tw = w.clone();
                let mut tacc = acc.clone();
                let mut n = 0u64;
                for s in stripes.iter_mut() {
                    n += sweep_stripe_block(
                        &setup,
                        rule,
                        s.q,
                        block_id as usize,
                        &mut tw,
                        &mut tacc,
                        &mut s.alpha,
                        &mut s.a_acc,
                        &mut scratch,
                    ) as u64;
                }
                let visit = v;
                v += 1;
                let mut dropped = false;
                match setup.faults.message_fault(worker, fe, fi) {
                    Some(MsgFault::Delay { millis }) => {
                        std::thread::sleep(Duration::from_millis(millis));
                    }
                    Some(MsgFault::Drop) => dropped = true,
                    None => {}
                }
                let fwd = Msg::Fwd {
                    seq: my_seq,
                    visit,
                    updates: n,
                    dropped,
                    block_id,
                    dw: Delta::encode(&w, &tw),
                    dacc: Delta::encode(&acc, &tacc),
                    stripes: stripes
                        .iter()
                        .map(|s| StripeMsg {
                            q: s.q as u32,
                            alpha: s.alpha.clone(),
                            a_acc: s.a_acc.clone(),
                        })
                        .collect(),
                };
                let _ = conn.send_tracked(my_seq, &fwd);
                my_seq += 1;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_log_round_trips() {
        let mut log = SchedLog::create("/dev/null", 0xabcd_ef01_2345_6789, 4);
        log.visit(2, 1, 117, &[2]);
        log.visit(0, 3, 94, &[0, 2]);
        log.death(1, 0, 2, 1);
        log.visit(3, 1, 88, &[3]);
        let sched = Schedule::parse(&log.buf).unwrap();
        assert_eq!(sched.fingerprint, 0xabcd_ef01_2345_6789);
        assert_eq!(sched.p, 4);
        assert_eq!(sched.deaths, 1);
        assert_eq!(sched.entries.len(), 3);
        assert_eq!(
            sched.entries[1],
            ScheduleEntry { worker: 0, block: 3, updates: 94, stripes: vec![0, 2] }
        );
    }

    #[test]
    fn schedule_parse_rejects_garbage() {
        assert!(Schedule::parse("nonsense\n").is_err());
        let ok = "dso-schedule v1\nfingerprint 00ff\np 2\nvisit 0 1 10 0\n";
        assert!(Schedule::parse(ok).is_ok());
        // Missing header fields.
        assert!(Schedule::parse("dso-schedule v1\np 2\n").is_err());
        assert!(Schedule::parse("dso-schedule v1\nfingerprint 00ff\n").is_err());
        // Malformed records.
        assert!(Schedule::parse("dso-schedule v1\nfingerprint 0\np 2\nvisit 0 1\n").is_err());
        assert!(Schedule::parse("dso-schedule v1\nfingerprint 0\np 2\nvisit 0 1 10\n").is_err());
        assert!(Schedule::parse("dso-schedule v1\nfingerprint 0\np 2\nzap 1\n").is_err());
        assert!(Schedule::parse("dso-schedule v1\nfingerprint zz\np 2\n").is_err());
    }

    #[test]
    fn worker_bin_resolution_prefers_config() {
        let mut cfg = TrainConfig::default();
        cfg.cluster.worker_bin = "/opt/custom/dso".into();
        assert_eq!(resolve_worker_bin(&cfg).unwrap(), PathBuf::from("/opt/custom/dso"));
        // With no override, resolution lands on *some* executable path
        // (current_exe in the test harness).
        cfg.cluster.worker_bin.clear();
        assert!(resolve_worker_bin(&cfg).is_ok());
    }

    #[test]
    fn ring_socket_paths_are_unique() {
        let a = ring_socket_path();
        let b = ring_socket_path();
        assert_ne!(a, b);
        assert!(a.to_string_lossy().contains("dso-ring-"));
    }
}
