//! The `dso` command-line launcher.
//!
//! ```text
//! dso train  [--config run.toml] [--data NAME] [--algo dso|sgd|psgd|bmrm]
//!            [--loss hinge|logistic|square] [--lambda X] [--epochs N]
//!            [--machines M] [--cores C] [--mode scalar|tile|dso-proc]
//!            [--simd auto|portable|avx2|avx512] [--scale S]
//!            [--eta0 X] [--dcd-init] [--replay] [--out results/run.csv]
//!            [--model-out model.dso] [--path f.libsvm]
//!            [--faults SPEC] [--checkpoint-every N] [--checkpoint PATH]
//!            [--resume PATH] [--heartbeat-ms N] [--death-timeout-ms N]
//!            [--sched-out PATH] [--worker-bin PATH]
//!            [--cache off|build|use|auto] [--cache-dir DIR]
//! dso exp    <table1|table2|fig2|fig3|fig4|fig5|serial-sweep|parallel-sweep|all>
//!            [--scale S] [--epochs-mul M] [--out DIR] [--seed N]
//! dso serve  --model model.dso --socket /tmp/dso-serve.sock
//!            [--simd auto|portable|avx2|avx512]
//! dso stats  [--name NAME | --all] [--scale S]
//! dso gen-data --name NAME --out FILE [--scale S] [--seed N]
//! dso inspect-artifacts
//! ```
//!
//! `train` drives the [`crate::api::Trainer`] facade: `--replay` runs
//! the Lemma-2 serial replay of the scalar DSO engine, `--model-out`
//! persists the fitted w in the libsvm-style model format, and
//! `--simd` pins the SIMD kernel backend (`auto` = *measured*
//! selection: every host-supported backend is micro-benchmarked for a
//! few milliseconds at setup and the observed winner runs; `portable`
//! = the autovec baseline, bit-identical to the pre-backend kernels;
//! `avx2` = force the gather/FMA backend; `avx512` = force the paired
//! 16-wide backend — either force is rejected, not silently degraded,
//! on hosts missing its features: avx2+fma resp. avx512f+avx2+fma).
//! The override exists for benchmarking and reproducibility.
//!
//! Fault tolerance (DESIGN.md §Fault-tolerance): `--faults` injects a
//! seeded fault schedule, e.g. `stall@1.0.1:30` (worker 1, epoch 0,
//! iter 1 stalls 30 ms), `die@2.0.2`, `drop@0.1.0`, `delay@3.0.1:5`,
//! or a sampled plan `rand:seed=7,die=0.01,stall=0.05`. Death and drop
//! faults need `--algo dso-async`; the synchronous ring accepts only
//! timing faults (stall/delay), which leave its trajectory
//! bit-identical. `--checkpoint-every N` with `--checkpoint PATH`
//! writes an atomic full-state snapshot every N epochs (scalar sync
//! DSO), and `--resume PATH` continues a run from one — bit-identical
//! to never having stopped.
//!
//! Multi-process transport (DESIGN.md §Transport): `--mode dso-proc`
//! runs one OS process per worker over Unix-domain sockets (implies
//! `--algo dso-async` unless overridden). `--heartbeat-ms` and
//! `--death-timeout-ms` tune death detection, `--sched-out PATH`
//! records the delivered-message schedule for bit-exact serial replay,
//! and `--worker-bin` overrides the spawned worker executable. The
//! kill@/partition@ fault kinds are proc-only: a real SIGKILL and a
//! real link partition at the same clock coordinates the thread ring
//! uses. The supervisor respawns workers via the hidden `__dso-worker`
//! subcommand — not part of the public surface.
//!
//! Out-of-core (DESIGN.md §Out-of-core): `--cache build --cache-dir D`
//! packs the training blocks once and writes a fingerprinted `.dsoblk`
//! cache under `D`; `--cache use` mmaps that file and trains with the
//! block payload demand-paged (bit-identical to the resident run, and
//! refused if the cache was packed under a different configuration).
//! `--cache auto` uses a matching cache when present, else builds one.
//!
//! Serving (DESIGN.md §Serving): `serve` loads a `--model` file and
//! answers libsvm-formatted predict requests over the framed transport
//! on `--socket` until a client sends `Shutdown`. The SIMD backend is
//! resolved once at startup (`--simd`, same semantics as training) and
//! reported in the stats; `Reload` hot-swaps the model — e.g. after a
//! `Trainer::fit_from` warm-start retrain — without dropping the
//! socket. See `examples/serve_roundtrip.rs` for the client side.

pub mod args;

use crate::config::TrainConfig;
use crate::exp::ExpOptions;
use args::Args;
use anyhow::Result;

pub fn main_entry(raw: Vec<String>) -> Result<i32> {
    crate::util::logger::init();
    let args = Args::parse(&raw).map_err(anyhow::Error::msg)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "exp" => cmd_exp(&args),
        "stats" => cmd_stats(&args),
        "gen-data" => cmd_gen_data(&args),
        "inspect-artifacts" => cmd_inspect_artifacts(),
        // Hidden: the dso-proc supervisor spawns `dso __dso-worker
        // --socket PATH --worker Q` for each ring member.
        "__dso-worker" => cmd_worker(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            Ok(2)
        }
    }
}

pub fn usage() -> String {
    "dso — Distributed Stochastic Optimization of the Regularized Risk\n\
     commands:\n\
     \x20 train               train a model (DSO or a baseline)\n\
     \x20 serve               serve a saved model over a Unix socket\n\
     \x20 exp <name>          reproduce a paper table/figure (or 'all')\n\
     \x20 stats               dataset summary (Table 2)\n\
     \x20 gen-data            export a synthetic dataset to libsvm\n\
     \x20 inspect-artifacts   list AOT artifacts and their status\n\
     run `dso <cmd> --help-flags` is not needed: see module docs / README.\n"
        .to_string()
}

fn build_train_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        TrainConfig::from_toml(&text).map_err(anyhow::Error::msg)?
    } else {
        TrainConfig::default()
    };
    if let Some(v) = args.get("data") {
        cfg.data.name = v.to_string();
    }
    if let Some(v) = args.get("path") {
        cfg.data.path = Some(v.to_string());
    }
    if let Some(v) = args.get("algo") {
        cfg.optim.algorithm = crate::config::Algorithm::parse(v).map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = args.get("loss") {
        cfg.model.loss = crate::config::LossKind::parse(v).map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = args.get("mode") {
        cfg.cluster.mode = crate::config::ExecMode::parse(v).map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = args.get("simd") {
        cfg.cluster.simd = crate::config::SimdKind::parse(v).map_err(anyhow::Error::msg)?;
    }
    cfg.model.lambda = args.get_f64("lambda", cfg.model.lambda).map_err(anyhow::Error::msg)?;
    cfg.optim.epochs = args.get_usize("epochs", cfg.optim.epochs).map_err(anyhow::Error::msg)?;
    cfg.optim.eta0 = args.get_f64("eta0", cfg.optim.eta0).map_err(anyhow::Error::msg)?;
    cfg.optim.dcd_init = cfg.optim.dcd_init || args.get_bool("dcd-init");
    cfg.optim.seed = args.get_u64("seed", cfg.optim.seed).map_err(anyhow::Error::msg)?;
    cfg.cluster.machines =
        args.get_usize("machines", cfg.cluster.machines).map_err(anyhow::Error::msg)?;
    cfg.cluster.cores = args.get_usize("cores", cfg.cluster.cores).map_err(anyhow::Error::msg)?;
    cfg.data.scale = args.get_f64("scale", cfg.data.scale).map_err(anyhow::Error::msg)?;
    cfg.data.seed = args.get_u64("data-seed", cfg.data.seed).map_err(anyhow::Error::msg)?;
    if let Some(v) = args.get("out") {
        cfg.monitor.out = v.to_string();
    }
    if let Some(v) = args.get("faults") {
        cfg.cluster.faults = v.to_string();
    }
    cfg.checkpoint.every =
        args.get_usize("checkpoint-every", cfg.checkpoint.every).map_err(anyhow::Error::msg)?;
    if let Some(v) = args.get("checkpoint") {
        cfg.checkpoint.path = v.to_string();
    }
    if let Some(v) = args.get("resume") {
        cfg.checkpoint.resume = v.to_string();
    }
    cfg.cluster.heartbeat_ms =
        args.get_u64("heartbeat-ms", cfg.cluster.heartbeat_ms).map_err(anyhow::Error::msg)?;
    cfg.cluster.death_timeout_ms = args
        .get_u64("death-timeout-ms", cfg.cluster.death_timeout_ms)
        .map_err(anyhow::Error::msg)?;
    if let Some(v) = args.get("sched-out") {
        cfg.cluster.sched_out = v.to_string();
    }
    if let Some(v) = args.get("worker-bin") {
        cfg.cluster.worker_bin = v.to_string();
    }
    if let Some(v) = args.get("cache") {
        cfg.cluster.cache = crate::config::CacheMode::parse(v).map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = args.get("cache-dir") {
        cfg.cluster.cache_dir = v.to_string();
    }
    // `--mode dso-proc` is only meaningful under the async algorithm;
    // select it when the user didn't pick one explicitly.
    if cfg.cluster.mode == crate::config::ExecMode::Proc
        && args.get("algo").is_none()
        && cfg.optim.algorithm == crate::config::Algorithm::Dso
    {
        cfg.optim.algorithm = crate::config::Algorithm::DsoAsync;
    }
    cfg.validate().map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

/// Entry point for the hidden `__dso-worker` subcommand. Everything the
/// worker needs beyond its identity arrives over the socket (config,
/// dataset, fingerprint), so the argument surface stays minimal.
fn cmd_worker(args: &Args) -> Result<i32> {
    args.check_known(&["socket", "worker"]).map_err(anyhow::Error::msg)?;
    let socket = args
        .get("socket")
        .ok_or_else(|| anyhow::anyhow!("__dso-worker requires --socket"))?;
    let worker: usize = args
        .get("worker")
        .ok_or_else(|| anyhow::anyhow!("__dso-worker requires --worker"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("__dso-worker: bad --worker (expected an index)"))?;
    crate::net::supervisor::worker_main(std::path::Path::new(socket), worker)?;
    Ok(0)
}

/// Load the dataset a config points at (registry or libsvm path).
pub fn load_dataset(cfg: &TrainConfig) -> Result<crate::data::Dataset> {
    match &cfg.data.path {
        Some(p) => Ok(crate::data::libsvm::read(std::path::Path::new(p), 0)?),
        None => crate::data::registry::generate(&cfg.data.name, cfg.data.scale, cfg.data.seed)
            .map_err(anyhow::Error::msg),
    }
}

fn cmd_train(args: &Args) -> Result<i32> {
    args.check_known(&[
        "config", "data", "path", "algo", "loss", "mode", "simd", "lambda", "epochs", "eta0",
        "dcd-init", "replay", "seed", "machines", "cores", "scale", "data-seed", "out",
        "model-out", "test-frac", "faults", "checkpoint-every", "checkpoint", "resume",
        "heartbeat-ms", "death-timeout-ms", "sched-out", "worker-bin", "cache", "cache-dir",
    ])
    .map_err(anyhow::Error::msg)?;
    let mut cfg = build_train_config(args)?;
    cfg.data.test_frac =
        args.get_f64("test-frac", cfg.data.test_frac).map_err(anyhow::Error::msg)?;
    let ds = load_dataset(&cfg)?;
    let (train, test) = ds.split(cfg.data.test_frac, cfg.data.seed);
    crate::log_info!(
        "training {} on {} (m={}, d={}, nnz={}) with {} workers",
        cfg.optim.algorithm.name(),
        train.name,
        train.m(),
        train.d(),
        train.nnz(),
        cfg.workers()
    );
    let fitted = crate::api::Trainer::new(cfg.clone())
        .replay(args.get_bool("replay"))
        .fit(&train, Some(&test))?;
    let r = &fitted.result;
    println!(
        "{}: objective={:.6} gap={:.3e} test_error={:.4} virtual={:.3}s wall={:.3}s updates={}",
        r.algorithm,
        r.final_primal,
        r.final_gap,
        r.history.col("test_error").and_then(|c| c.last().copied()).unwrap_or(f64::NAN),
        r.total_virtual_s,
        r.total_wall_s,
        r.total_updates
    );
    if !cfg.monitor.out.is_empty() {
        let p = std::path::PathBuf::from(&cfg.monitor.out);
        r.history.write_csv(&p)?;
        println!("history -> {}", p.display());
    }
    if let Some(out) = args.get("model-out") {
        let p = std::path::PathBuf::from(out);
        fitted.save(&p)?;
        println!("model -> {}", p.display());
    }
    Ok(0)
}

/// `dso serve`: stand up the model server (DESIGN.md §Serving) and
/// block until a client sends `Shutdown`. Per-request stats stream to
/// the log; the final counters print on exit.
fn cmd_serve(args: &Args) -> Result<i32> {
    args.check_known(&["model", "socket", "simd"]).map_err(anyhow::Error::msg)?;
    let model = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("serve requires --model <path to a saved model>"))?;
    let socket = args
        .get("socket")
        .ok_or_else(|| anyhow::anyhow!("serve requires --socket <unix socket path>"))?;
    let mut opts = crate::serve::ServeOptions::new(model, socket);
    if let Some(v) = args.get("simd") {
        opts.simd = crate::config::SimdKind::parse(v).map_err(anyhow::Error::msg)?;
    }
    let mut server = crate::serve::Server::bind(&opts)?;
    crate::log_info!(
        "serving {} (d={}, backend={}) on {}",
        model,
        server.model_dim(),
        server.backend(),
        socket
    );
    if let Some(report) = server.autotune_report() {
        for m in &report.measured {
            crate::log_info!(
                "simd auto: {} measured {:.0} entries/s over {} reps{}",
                m.level.name(),
                m.units_per_sec,
                m.reps,
                if m.level == report.chosen { " (chosen)" } else { "" }
            );
        }
    }
    let mut obs = |stat: &crate::serve::RequestStat| {
        crate::log_info!(
            "predict #{}: {} rows ({} nnz) in {:.3} ms [{}]",
            stat.id,
            stat.rows,
            stat.nnz,
            stat.latency_s * 1e3,
            stat.backend
        );
    };
    server.run(&mut obs)?;
    let st = server.stats();
    println!(
        "served={} rows={} errors={} reloads={} mean_latency={:.3}ms rows/s={:.0} backend={}",
        st.served,
        st.rows,
        st.errors,
        st.reloads,
        st.mean_latency_s() * 1e3,
        st.rows_per_sec(),
        st.backend
    );
    Ok(0)
}

fn cmd_exp(args: &Args) -> Result<i32> {
    args.check_known(&["scale", "epochs-mul", "out", "seed"]).map_err(anyhow::Error::msg)?;
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: dso exp <name>; names: {}", crate::exp::ALL.join(", ")))?;
    let mut opts = ExpOptions::default();
    opts.scale = args.get_f64("scale", opts.scale).map_err(anyhow::Error::msg)?;
    opts.epochs_mul =
        args.get_f64("epochs-mul", opts.epochs_mul).map_err(anyhow::Error::msg)?;
    opts.seed = args.get_u64("seed", opts.seed).map_err(anyhow::Error::msg)?;
    if let Some(v) = args.get("out") {
        opts.out_dir = v.into();
    }
    crate::exp::run(name, &opts)?;
    Ok(0)
}

fn cmd_stats(args: &Args) -> Result<i32> {
    args.check_known(&["name", "all", "scale", "seed"]).map_err(anyhow::Error::msg)?;
    let scale = args.get_f64("scale", 1.0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    println!("{}", crate::data::DatasetStats::header());
    let names: Vec<&str> = match args.get("name") {
        Some(n) => vec![n],
        None => crate::data::registry::NAMES.to_vec(),
    };
    for name in names {
        let ds = crate::data::registry::generate(name, scale, seed)
            .map_err(anyhow::Error::msg)?;
        println!("{}", ds.stats().row());
    }
    Ok(0)
}

fn cmd_gen_data(args: &Args) -> Result<i32> {
    args.check_known(&["name", "out", "scale", "seed"]).map_err(anyhow::Error::msg)?;
    let name = args
        .get("name")
        .ok_or_else(|| anyhow::anyhow!("gen-data requires --name"))?;
    let out = args.get("out").ok_or_else(|| anyhow::anyhow!("gen-data requires --out"))?;
    let scale = args.get_f64("scale", 1.0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 42).map_err(anyhow::Error::msg)?;
    let ds =
        crate::data::registry::generate(name, scale, seed).map_err(anyhow::Error::msg)?;
    crate::data::libsvm::write(&ds, std::path::Path::new(out))?;
    println!("wrote {} (m={}, d={}, nnz={})", out, ds.m(), ds.d(), ds.nnz());
    Ok(0)
}

fn cmd_inspect_artifacts() -> Result<i32> {
    match crate::runtime::Manifest::load_default() {
        Err(e) => {
            println!("artifacts: NOT BUILT ({e}); run `make artifacts`");
            Ok(1)
        }
        Ok(m) => {
            println!(
                "artifacts @ {} (jax {}):",
                m.dir.display(),
                m.jax_version
            );
            println!("{:<36} {:>6} {:>6} {:>12}", "name", "bm", "bd", "vmem_bytes");
            for e in &m.entries {
                println!("{:<36} {:>6} {:>6} {:>12}", e.name, e.bm, e.bd, e.vmem_bytes);
            }
            Ok(0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(toks: &[&str]) -> Result<i32> {
        main_entry(toks.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn help_and_unknown() {
        assert_eq!(run(&["help"]).unwrap(), 0);
        assert_eq!(run(&["bogus"]).unwrap(), 2);
    }

    #[test]
    fn stats_runs() {
        assert_eq!(run(&["stats", "--name", "real-sim", "--scale", "0.05"]).unwrap(), 0);
        assert!(run(&["stats", "--name", "nope", "--scale", "0.05"]).is_err());
    }

    #[test]
    fn train_quick() {
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "3",
                "--machines", "2", "--cores", "1"
            ])
            .unwrap(),
            0
        );
    }

    #[test]
    fn train_rejects_unknown_flag() {
        assert!(run(&["train", "--lamda", "0.1"]).is_err());
    }

    /// `--simd portable` pins the backend through the CLI; a bogus
    /// backend name is an actionable parse error; forced hardware
    /// backends run or refuse loudly, never silently degrade.
    #[test]
    fn train_simd_override() {
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "1", "--cores", "1", "--simd", "portable"
            ])
            .unwrap(),
            0
        );
        let err = run(&["train", "--data", "real-sim", "--simd", "neon"]).unwrap_err();
        assert!(format!("{err}").contains("simd backend"), "{err}");
        // Forcing a hardware backend either runs (host supports it) or
        // fails with the validate() message naming the fix — never
        // silent.
        for (flag, supported) in [
            ("avx2", crate::simd::avx2_supported()),
            ("avx512", crate::simd::avx512_supported()),
        ] {
            let forced = run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "1",
                "--machines", "1", "--cores", "1", "--simd", flag,
            ]);
            if supported {
                assert_eq!(forced.unwrap(), 0, "--simd {flag}");
            } else {
                assert!(format!("{}", forced.unwrap_err()).contains(flag), "--simd {flag}");
            }
        }
    }

    /// `--replay` reaches the Lemma-2 serial replay through the facade
    /// (it used to be test-only).
    #[test]
    fn train_replay_runs() {
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "2", "--cores", "1", "--replay"
            ])
            .unwrap(),
            0
        );
    }

    /// `--replay` on a non-DSO algorithm is an actionable error, not a
    /// silent fallback.
    #[test]
    fn train_replay_rejects_non_dso() {
        let err = run(&[
            "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2", "--algo",
            "sgd", "--replay",
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("replay"), "{err}");
    }

    /// `--model-out` persists a loadable model whose w matches the run.
    #[test]
    fn train_model_out_roundtrips() {
        let out = std::env::temp_dir().join("dso-cli-train.model");
        let out_s = out.to_str().unwrap();
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "1", "--cores", "1", "--model-out", out_s
            ])
            .unwrap(),
            0
        );
        let model = crate::api::Model::load(&out).unwrap();
        assert!(model.w.iter().any(|&v| v != 0.0));
        assert_eq!(model.algorithm, "dso");
        std::fs::remove_file(&out).ok();
    }

    /// `--mode tile` on a build without the `xla` feature must surface
    /// the stub's actionable error through the full CLI → coordinator →
    /// runtime routing, not a panic or a silent fallback to scalar.
    #[cfg(not(feature = "xla"))]
    #[test]
    fn train_tile_mode_reports_gated_stub_error() {
        let err = run(&[
            "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "1", "--mode",
            "tile",
        ])
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("tile mode requires the PJRT runtime"), "msg: {msg}");
        assert!(msg.contains("--features xla"), "msg: {msg}");
    }

    #[test]
    fn gen_data_roundtrip() {
        let out = std::env::temp_dir().join("dso-cli-gen.libsvm");
        let out_s = out.to_str().unwrap();
        assert_eq!(
            run(&["gen-data", "--name", "news20", "--scale", "0.03", "--out", out_s]).unwrap(),
            0
        );
        let ds = crate::data::libsvm::read(&out, 0).unwrap();
        assert!(ds.m() > 0);
        std::fs::remove_file(&out).ok();
    }

    #[test]
    fn train_from_config_file() {
        let dir = std::env::temp_dir().join("dso-cli-cfg");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg_path = dir.join("run.toml");
        std::fs::write(
            &cfg_path,
            "[data]\nname = \"real-sim\"\nscale = 0.05\n[optim]\nepochs = 2\n",
        )
        .unwrap();
        assert_eq!(run(&["train", "--config", cfg_path.to_str().unwrap()]).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `serve` refuses to start without its two required flags, and
    /// refuses an unloadable model before binding anything.
    #[test]
    fn serve_requires_model_and_socket() {
        assert!(run(&["serve"]).is_err());
        assert!(run(&["serve", "--model", "/nonexistent.model"]).is_err());
        let err = run(&[
            "serve", "--model", "/nonexistent.model", "--socket", "/tmp/dso-cli-serve.sock",
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("loading model"), "{err}");
    }

    #[test]
    fn exp_requires_name() {
        assert!(run(&["exp"]).is_err());
        assert!(run(&["exp", "nope"]).is_err());
    }

    /// `--faults`: timing faults pass validation on the sync engine;
    /// death faults are routed to dso-async with an actionable error;
    /// on `--algo dso-async` an injected death trains through.
    #[test]
    fn train_faults_flag() {
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "2", "--cores", "1", "--faults", "stall@0.0.1:5",
            ])
            .unwrap(),
            0
        );
        let err = run(&[
            "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
            "--machines", "2", "--cores", "1", "--faults", "die@0.0.0",
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("dso-async"), "{err}");
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "2", "--cores", "1", "--algo", "dso-async", "--faults",
                "die@1.0.1",
            ])
            .unwrap(),
            0
        );
    }

    /// `--cache build` leaves a `.dsoblk` behind that `--cache use`
    /// trains from; `--cache use` against an empty dir is an error.
    #[test]
    fn train_cache_build_then_use() {
        let dir = std::env::temp_dir().join("dso-cli-cache");
        std::fs::remove_dir_all(&dir).ok();
        let dir_s = dir.to_str().unwrap();
        // No cache yet: `use` must refuse rather than silently repack.
        let err = run(&[
            "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "1",
            "--machines", "2", "--cores", "1", "--cache", "use", "--cache-dir", dir_s,
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("cache"), "{err}");
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "2", "--cores", "1", "--cache", "build", "--cache-dir", dir_s,
            ])
            .unwrap(),
            0
        );
        let packed: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map_or(false, |x| x == "dsoblk"))
            .collect();
        assert_eq!(packed.len(), 1, "expected exactly one .dsoblk cache file");
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "2", "--cores", "1", "--cache", "use", "--cache-dir", dir_s,
            ])
            .unwrap(),
            0
        );
        // `--cache use` without a dir is a validation error.
        let err = run(&[
            "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "1",
            "--machines", "2", "--cores", "1", "--cache", "use",
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("cache_dir"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `--checkpoint-every`/`--checkpoint` write a snapshot the
    /// `--resume` route accepts.
    #[test]
    fn train_checkpoint_then_resume() {
        let ck = std::env::temp_dir().join("dso-cli-ck.txt");
        let ck_s = ck.to_str().unwrap();
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
                "--machines", "2", "--cores", "1", "--checkpoint-every", "1",
                "--checkpoint", ck_s,
            ])
            .unwrap(),
            0
        );
        assert!(ck.exists());
        assert_eq!(
            run(&[
                "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "4",
                "--machines", "2", "--cores", "1", "--resume", ck_s,
            ])
            .unwrap(),
            0
        );
        // `--checkpoint-every` without a path is an actionable error.
        let err = run(&[
            "train", "--data", "real-sim", "--scale", "0.05", "--epochs", "2",
            "--machines", "2", "--cores", "1", "--checkpoint-every", "1",
        ])
        .unwrap_err();
        assert!(format!("{err}").contains("checkpoint"), "{err}");
        std::fs::remove_file(&ck).ok();
    }
}
