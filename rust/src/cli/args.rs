//! Minimal argument parser (clap is not in the offline crate set).
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! `--flag`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(raw: &[String]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("unexpected bare '--'".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Value follows unless the next token is a flag/end.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            a.flags.insert(name.to_string(), it.next().unwrap().clone());
                        }
                        _ => {
                            a.flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Error if unknown flags remain (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k}; known: {}", known.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        // NB: a bare flag followed by a non-flag token consumes it as
        // its value ("--verbose x" ⇒ verbose=x); boolean flags must be
        // last or followed by another flag.
        let a = parse(&["train", "x", "--lambda", "0.001", "--algo=dso", "--verbose"]);
        assert_eq!(a.positional, vec!["train", "x"]);
        assert_eq!(a.get("lambda"), Some("0.001"));
        assert_eq!(a.get("algo"), Some("dso"));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--x", "2.5", "--n", "7"]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 7);
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        assert!(a.get_f64("n", 0.0).is_ok());
        let b = parse(&["--bad", "zz"]);
        assert!(b.get_f64("bad", 0.0).is_err());
        assert!(b.get_usize("bad", 0).is_err());
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse(&["--flag"]);
        assert!(a.get_bool("flag"));
        let b = parse(&["--flag", "--other", "v"]);
        assert!(b.get_bool("flag"));
        assert_eq!(b.get("other"), Some("v"));
    }

    #[test]
    fn check_known_catches_typos() {
        let a = parse(&["--lambda", "1"]);
        assert!(a.check_known(&["lambda"]).is_ok());
        assert!(a.check_known(&["lamda"]).is_err());
    }

    #[test]
    fn bare_double_dash_rejected() {
        assert!(Args::parse(&["--".to_string()]).is_err());
    }
}
