//! Typed configuration for training runs, parsed from the TOML subset
//! in [`toml`]. Every experiment driver and the CLI build on this; the
//! same struct can also be constructed programmatically (see
//! `examples/`).

pub mod toml;

use self::toml::Doc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    Hinge,
    Logistic,
    Square,
}

impl LossKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "hinge" | "svm" => Ok(LossKind::Hinge),
            "logistic" | "logreg" => Ok(LossKind::Logistic),
            "square" | "squared" => Ok(LossKind::Square),
            other => Err(format!("unknown loss '{other}' (hinge|logistic|square)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Hinge => "hinge",
            LossKind::Logistic => "logistic",
            LossKind::Square => "square",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegKind {
    L2,
    L1,
}

impl RegKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "l2" | "L2" => Ok(RegKind::L2),
            "l1" | "L1" => Ok(RegKind::L1),
            other => Err(format!("unknown regularizer '{other}' (l1|l2)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RegKind::L2 => "l2",
            RegKind::L1 => "l1",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    Const,
    InvSqrt,
    AdaGrad,
    /// Per-coordinate η₀/√(1+Σg²) (Cutkosky & Busa-Fekete,
    /// arXiv:1802.05811): AdaGrad's accumulated statistic with a unit
    /// offset instead of the ε floor, bounding the rate by η₀.
    Adaptive,
}

impl StepKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "const" | "constant" => Ok(StepKind::Const),
            "invsqrt" | "inv_sqrt" => Ok(StepKind::InvSqrt),
            "adagrad" => Ok(StepKind::AdaGrad),
            "adaptive" => Ok(StepKind::Adaptive),
            other => Err(format!(
                "unknown step schedule '{other}' (const|invsqrt|adagrad|adaptive)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Const => "const",
            StepKind::InvSqrt => "invsqrt",
            StepKind::AdaGrad => "adagrad",
            StepKind::Adaptive => "adaptive",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Dso,
    /// NOMAD-style asynchronous DSO (the paper's §6 extension).
    DsoAsync,
    Sgd,
    Psgd,
    Bmrm,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "dso" => Ok(Algorithm::Dso),
            "dso-async" | "async" => Ok(Algorithm::DsoAsync),
            "sgd" => Ok(Algorithm::Sgd),
            "psgd" => Ok(Algorithm::Psgd),
            "bmrm" => Ok(Algorithm::Bmrm),
            other => Err(format!(
                "unknown algorithm '{other}' (dso|dso-async|sgd|psgd|bmrm)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Dso => "dso",
            Algorithm::DsoAsync => "dso-async",
            Algorithm::Sgd => "sgd",
            Algorithm::Psgd => "psgd",
            Algorithm::Bmrm => "bmrm",
        }
    }
}

/// How rows/columns are partitioned across workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    /// Equal index counts (paper's default).
    Even,
    /// Contiguous blocks balanced by nonzero counts — keeps
    /// |Ω^(q,r)| ≈ |Ω|/p² on skewed data (Theorem 1's load assumption).
    Balanced,
}

impl PartitionKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "even" => Ok(PartitionKind::Even),
            "balanced" | "nnz" => Ok(PartitionKind::Balanced),
            other => Err(format!("unknown partition '{other}' (even|balanced)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionKind::Even => "even",
            PartitionKind::Balanced => "balanced",
        }
    }
}

/// Which SIMD kernel backend the scalar-mode sweeps use (DESIGN.md
/// §SIMD-backend). Resolved once per run by `simd::resolve` and
/// recorded in the sweep plan; the CLI override is `--simd`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdKind {
    /// Measured selection (the default): the micro-autotune times every
    /// host-supported backend for a few milliseconds at setup and keeps
    /// the observed winner — not a feature-flag guess.
    Auto,
    /// Force the autovectorized portable backend (bit-identical to the
    /// pre-backend kernels — the reproducibility baseline).
    Portable,
    /// Force the AVX2 backend. Rejected by `validate()` on hosts
    /// without avx2+fma, so a benchmark override can never silently
    /// fall back.
    Avx2,
    /// Force the AVX-512 paired-chunk backend. Rejected by `validate()`
    /// on hosts without avx512f+avx2+fma — same no-silent-fallback
    /// contract as `avx2`.
    Avx512,
}

impl SimdKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(SimdKind::Auto),
            "portable" | "scalar" => Ok(SimdKind::Portable),
            "avx2" => Ok(SimdKind::Avx2),
            "avx512" => Ok(SimdKind::Avx512),
            other => {
                Err(format!("unknown simd backend '{other}' (auto|portable|avx2|avx512)"))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdKind::Auto => "auto",
            SimdKind::Portable => "portable",
            SimdKind::Avx2 => "avx2",
            SimdKind::Avx512 => "avx512",
        }
    }
}

/// Out-of-core packed-block cache policy (DESIGN.md §Out-of-core).
/// Controls whether `PackedBlocks` are serialized to / mmap'd from a
/// `.dsoblk` file under `cluster.cache_dir`; the CLI override is
/// `--cache`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheMode {
    /// No cache: pack in memory every run (the default).
    Off,
    /// Pack in memory, write the cache file, then train from the
    /// resident tables (a warm-up run that leaves a cache behind).
    Build,
    /// Require the cache file: mmap it and train out-of-core, refusing
    /// to start if it is missing or carries a foreign fingerprint.
    Use,
    /// `Use` when a fingerprint-matching cache exists, else `Build`.
    Auto,
}

impl CacheMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "off" | "none" => Ok(CacheMode::Off),
            "build" | "pack" => Ok(CacheMode::Build),
            "use" | "mmap" => Ok(CacheMode::Use),
            "auto" => Ok(CacheMode::Auto),
            other => Err(format!("unknown cache mode '{other}' (off|build|use|auto)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CacheMode::Off => "off",
            CacheMode::Build => "build",
            CacheMode::Use => "use",
            CacheMode::Auto => "auto",
        }
    }
}

/// How DSO executes block updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Faithful Algorithm 1: sequential scalar updates over block nnz.
    Scalar,
    /// Tile-batched updates through the AOT Pallas kernel (dense data).
    Tile,
    /// One OS process per worker over Unix-domain sockets — the real
    /// transport (DESIGN.md §Transport). Requires `dso-async`.
    Proc,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "scalar" => Ok(ExecMode::Scalar),
            "tile" => Ok(ExecMode::Tile),
            "dso-proc" | "proc" => Ok(ExecMode::Proc),
            other => Err(format!("unknown exec mode '{other}' (scalar|tile|dso-proc)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Scalar => "scalar",
            ExecMode::Tile => "tile",
            ExecMode::Proc => "dso-proc",
        }
    }
}

#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Registry name (generated) — ignored if `path` is set.
    pub name: String,
    /// Optional path to a libsvm file.
    pub path: Option<String>,
    pub scale: f64,
    pub seed: u64,
    pub test_frac: f64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self { name: "real-sim".into(), path: None, scale: 1.0, seed: 42, test_frac: 0.2 }
    }
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub loss: LossKind,
    pub reg: RegKind,
    pub lambda: f64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self { loss: LossKind::Hinge, reg: RegKind::L2, lambda: 1e-4 }
    }
}

#[derive(Clone, Debug)]
pub struct OptimConfig {
    pub algorithm: Algorithm,
    pub step: StepKind,
    pub eta0: f64,
    pub epochs: usize,
    /// Warm-start parameters with local dual coordinate descent (App. B).
    pub dcd_init: bool,
    pub seed: u64,
}

impl Default for OptimConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Dso,
            step: StepKind::AdaGrad,
            eta0: 0.1,
            epochs: 50,
            dcd_init: false,
            seed: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Simulated machines.
    pub machines: usize,
    /// Threads per machine. Workers p = machines × cores.
    pub cores: usize,
    /// Simulated per-message latency (models T_c's fixed part).
    pub latency_us: f64,
    /// Simulated bandwidth in MB/s (T_c's size-dependent part).
    pub bandwidth_mbps: f64,
    pub mode: ExecMode,
    /// Updates per inner iteration per worker; 0 = sweep every nnz in
    /// the active block once (paper's default).
    pub updates_per_block: usize,
    /// Tile engine: batched saddle steps per sub-tile per block visit.
    /// One scalar sweep performs |Ω_block| sequential updates; several
    /// batched steps per visit keep per-epoch progress comparable.
    pub tile_iters: usize,
    /// Row/column partitioning strategy.
    pub partition: PartitionKind,
    /// SIMD kernel backend request (auto = runtime detection).
    pub simd: SimdKind,
    /// Fault-injection plan ([`crate::net::FaultPlan`] grammar): either
    /// explicit events (`"die@1.0.2,stall@0.1.0:20"`) or a sampled
    /// schedule (`"rand:seed=7,die=0.01,stall=0.05"`). Empty = none.
    pub faults: String,
    /// Process mode: idle-worker heartbeat period in milliseconds.
    pub heartbeat_ms: u64,
    /// Process mode: how long a silent worker may stay silent before
    /// the supervisor declares it dead (and SIGKILLs a hung child).
    /// Reconnects after a `partition@` fault must land inside this.
    pub death_timeout_ms: u64,
    /// Process mode: where the recorded message schedule is written
    /// (empty = don't record). Feed back via `replay_recorded_schedule`
    /// to re-execute the exact interleaving serially.
    pub sched_out: String,
    /// Process mode: path to the worker binary. Empty = `$DSO_WORKER_BIN`
    /// if set, else the current executable (re-exec'd with the hidden
    /// `__dso-worker` subcommand).
    pub worker_bin: String,
    /// Out-of-core packed-block cache policy (off|build|use|auto).
    pub cache: CacheMode,
    /// Directory holding `.dsoblk` cache files. Required (nonempty)
    /// whenever `cache != off`.
    pub cache_dir: String,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            machines: 1,
            cores: 4,
            latency_us: 100.0,
            bandwidth_mbps: 1000.0,
            mode: ExecMode::Scalar,
            updates_per_block: 0,
            tile_iters: 8,
            partition: PartitionKind::Even,
            simd: SimdKind::Auto,
            faults: String::new(),
            heartbeat_ms: 50,
            death_timeout_ms: 1500,
            sched_out: String::new(),
            worker_bin: String::new(),
            cache: CacheMode::Off,
            cache_dir: String::new(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct MonitorConfig {
    /// Evaluate every `every` epochs (0 disables periodic evaluation).
    pub every: usize,
    /// Where to write the per-epoch CSV (empty = don't write).
    pub out: String,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        Self { every: 1, out: String::new() }
    }
}

/// Epoch-boundary checkpointing (sync DSO engine only — the other
/// algorithms keep no cross-epoch saddle state worth snapshotting).
#[derive(Clone, Debug, Default)]
pub struct CheckpointConfig {
    /// Write a checkpoint every `every` epochs (0 disables).
    pub every: usize,
    /// Where the checkpoint file goes (atomic write-temp-rename).
    pub path: String,
    /// Resume from this checkpoint before the first epoch (empty = cold
    /// start). The run continues at the saved epoch + 1 and reproduces
    /// the uninterrupted trajectory bit-identically.
    pub resume: String,
}

#[derive(Clone, Debug, Default)]
pub struct TrainConfig {
    pub data: DataConfig,
    pub model: ModelConfig,
    pub optim: OptimConfig,
    pub cluster: ClusterConfig,
    pub monitor: MonitorConfig,
    pub checkpoint: CheckpointConfig,
}

impl TrainConfig {
    pub fn workers(&self) -> usize {
        self.cluster.machines * self.cluster.cores
    }

    /// Parse from TOML text, starting from defaults.
    pub fn from_toml(text: &str) -> Result<TrainConfig, String> {
        let doc = Doc::parse(text).map_err(|e| e.to_string())?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &Doc) -> Result<TrainConfig, String> {
        let mut c = TrainConfig::default();
        let f64_of = |k: &str, d: f64| doc.get_f64(k).unwrap_or(d);
        let usize_of = |k: &str, d: usize| {
            doc.get_i64(k).map(|v| v.max(0) as usize).unwrap_or(d)
        };

        if let Some(s) = doc.get_str("data.name") {
            c.data.name = s.to_string();
        }
        if let Some(s) = doc.get_str("data.path") {
            c.data.path = Some(s.to_string());
        }
        c.data.scale = f64_of("data.scale", c.data.scale);
        c.data.seed = doc.get_i64("data.seed").map(|v| v as u64).unwrap_or(c.data.seed);
        c.data.test_frac = f64_of("data.test_frac", c.data.test_frac);

        if let Some(s) = doc.get_str("model.loss") {
            c.model.loss = LossKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("model.regularizer") {
            c.model.reg = RegKind::parse(s)?;
        }
        c.model.lambda = f64_of("model.lambda", c.model.lambda);

        if let Some(s) = doc.get_str("optim.algorithm") {
            c.optim.algorithm = Algorithm::parse(s)?;
        }
        if let Some(s) = doc.get_str("optim.step") {
            c.optim.step = StepKind::parse(s)?;
        }
        c.optim.eta0 = f64_of("optim.eta0", c.optim.eta0);
        c.optim.epochs = usize_of("optim.epochs", c.optim.epochs);
        c.optim.dcd_init = doc.get_bool("optim.dcd_init").unwrap_or(c.optim.dcd_init);
        c.optim.seed = doc.get_i64("optim.seed").map(|v| v as u64).unwrap_or(c.optim.seed);

        c.cluster.machines = usize_of("cluster.machines", c.cluster.machines);
        c.cluster.cores = usize_of("cluster.cores", c.cluster.cores);
        c.cluster.latency_us = f64_of("cluster.latency_us", c.cluster.latency_us);
        c.cluster.bandwidth_mbps = f64_of("cluster.bandwidth_mbps", c.cluster.bandwidth_mbps);
        if let Some(s) = doc.get_str("cluster.mode") {
            c.cluster.mode = ExecMode::parse(s)?;
        }
        c.cluster.updates_per_block =
            usize_of("cluster.updates_per_block", c.cluster.updates_per_block);
        c.cluster.tile_iters = usize_of("cluster.tile_iters", c.cluster.tile_iters).max(1);
        if let Some(s) = doc.get_str("cluster.partition") {
            c.cluster.partition = PartitionKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("cluster.simd") {
            c.cluster.simd = SimdKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("cluster.faults") {
            c.cluster.faults = s.to_string();
        }
        c.cluster.heartbeat_ms = doc
            .get_i64("cluster.heartbeat_ms")
            .map(|v| v.max(0) as u64)
            .unwrap_or(c.cluster.heartbeat_ms);
        c.cluster.death_timeout_ms = doc
            .get_i64("cluster.death_timeout_ms")
            .map(|v| v.max(0) as u64)
            .unwrap_or(c.cluster.death_timeout_ms);
        if let Some(s) = doc.get_str("cluster.sched_out") {
            c.cluster.sched_out = s.to_string();
        }
        if let Some(s) = doc.get_str("cluster.worker_bin") {
            c.cluster.worker_bin = s.to_string();
        }
        if let Some(s) = doc.get_str("cluster.cache") {
            c.cluster.cache = CacheMode::parse(s)?;
        }
        if let Some(s) = doc.get_str("cluster.cache_dir") {
            c.cluster.cache_dir = s.to_string();
        }

        c.checkpoint.every = usize_of("checkpoint.every", c.checkpoint.every);
        if let Some(s) = doc.get_str("checkpoint.path") {
            c.checkpoint.path = s.to_string();
        }
        if let Some(s) = doc.get_str("checkpoint.resume") {
            c.checkpoint.resume = s.to_string();
        }

        c.monitor.every = usize_of("monitor.every", c.monitor.every);
        if let Some(s) = doc.get_str("monitor.out") {
            c.monitor.out = s.to_string();
        }

        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.model.lambda <= 0.0 {
            return Err(format!("lambda must be > 0, got {}", self.model.lambda));
        }
        if self.optim.eta0 <= 0.0 {
            return Err(format!("eta0 must be > 0, got {}", self.optim.eta0));
        }
        if self.cluster.machines == 0 || self.cluster.cores == 0 {
            return Err("cluster.machines and cluster.cores must be >= 1".into());
        }
        if !(0.0..1.0).contains(&self.data.test_frac) {
            return Err(format!("test_frac must be in [0,1), got {}", self.data.test_frac));
        }
        if self.data.scale <= 0.0 {
            return Err("data.scale must be > 0".into());
        }
        if self.optim.epochs == 0 {
            return Err("epochs must be >= 1".into());
        }
        // Forced backends validate against the host with the same
        // message `simd::resolve` panics with — the kind list and the
        // host-supported menu both come from the simd module, so
        // adding a backend can't leave this check stale.
        let forced_unsupported = match self.cluster.simd {
            SimdKind::Avx2 => !crate::simd::avx2_supported(),
            SimdKind::Avx512 => !crate::simd::avx512_supported(),
            SimdKind::Auto | SimdKind::Portable => false,
        };
        if forced_unsupported {
            return Err(crate::simd::forced_unsupported_msg(self.cluster.simd));
        }
        if self.model.loss == LossKind::Square && self.model.reg == RegKind::L1 {
            // LASSO is supported by the losses module; the DSO projection
            // boxes in App. B are for SVM/logistic. Allowed, but the w box
            // uses the L2 formula — warn via validation note (not fatal).
        }
        if self.cluster.mode == ExecMode::Proc {
            if self.optim.algorithm != Algorithm::DsoAsync {
                return Err(format!(
                    "mode = \"dso-proc\" runs the asynchronous ring across worker \
                     processes; set algorithm = \"dso-async\" (got \"{}\")",
                    self.optim.algorithm.name()
                ));
            }
            if self.cluster.heartbeat_ms == 0 || self.cluster.death_timeout_ms == 0 {
                return Err(
                    "mode = \"dso-proc\" needs cluster.heartbeat_ms > 0 and \
                     cluster.death_timeout_ms > 0 (death detection is timeout-based)"
                        .into(),
                );
            }
            if self.cluster.death_timeout_ms <= self.cluster.heartbeat_ms {
                return Err(format!(
                    "cluster.death_timeout_ms ({}) must exceed cluster.heartbeat_ms \
                     ({}) or every idle worker is declared dead between heartbeats",
                    self.cluster.death_timeout_ms, self.cluster.heartbeat_ms
                ));
            }
        }
        if !self.cluster.faults.is_empty() {
            let dso = matches!(self.optim.algorithm, Algorithm::Dso | Algorithm::DsoAsync);
            if !dso {
                return Err(format!(
                    "cluster.faults targets the DSO ring; algorithm \"{}\" has no \
                     token flow to perturb (use dso or dso-async)",
                    self.optim.algorithm.name()
                ));
            }
            let plan = crate::net::FaultPlan::parse_with(
                &self.cluster.faults,
                self.workers().max(1),
                self.optim.epochs,
            )?;
            if (plan.has_deaths() || plan.has_drops())
                && self.optim.algorithm != Algorithm::DsoAsync
            {
                return Err(
                    "fault plan injects worker death or message drops, which the \
                     bulk-synchronous dso engine cannot survive (a lost ring token \
                     deadlocks the epoch barrier); use algorithm = \"dso-async\", \
                     or restrict the plan to stall/delay"
                        .into(),
                );
            }
            if (plan.has_kills() || plan.has_partitions()) && self.cluster.mode != ExecMode::Proc
            {
                return Err(
                    "kill@ (real SIGKILL) and partition@ (link fault) only exist in \
                     the multi-process transport; use mode = \"dso-proc\", or map to \
                     die@/stall@ for the in-thread ring"
                        .into(),
                );
            }
        }
        if self.cluster.cache != CacheMode::Off {
            if self.cluster.cache_dir.is_empty() {
                return Err(format!(
                    "cluster.cache = \"{}\" requires cluster.cache_dir (where the \
                     .dsoblk files live)",
                    self.cluster.cache.name()
                ));
            }
            if !matches!(self.optim.algorithm, Algorithm::Dso | Algorithm::DsoAsync) {
                return Err(format!(
                    "the packed-block cache serves the DSO sweep engines; algorithm \
                     \"{}\" never packs blocks (use dso or dso-async, or cache = \"off\")",
                    self.optim.algorithm.name()
                ));
            }
            if self.cluster.mode == ExecMode::Tile {
                return Err(
                    "mode = \"tile\" batches dense sub-tiles and does not read the \
                     packed sparse blocks the cache stores; use mode = \"scalar\" or \
                     \"dso-proc\", or cache = \"off\""
                        .into(),
                );
            }
        }
        let checkpointing = self.checkpoint.every > 0 || !self.checkpoint.resume.is_empty();
        if checkpointing {
            if self.optim.algorithm != Algorithm::Dso || self.cluster.mode != ExecMode::Scalar {
                return Err(
                    "checkpointing is supported for the synchronous scalar DSO engine \
                     (algorithm = \"dso\", mode = \"scalar\"), where epoch boundaries \
                     hold the full saddle state"
                        .into(),
                );
            }
            if self.checkpoint.every > 0 && self.checkpoint.path.is_empty() {
                return Err("checkpoint.every > 0 requires checkpoint.path".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
[data]
name = "kdda"
scale = 0.5
seed = 7
test_frac = 0.1

[model]
loss = "logistic"
regularizer = "l2"
lambda = 1e-5

[optim]
algorithm = "dso"
step = "adagrad"
eta0 = 0.2
epochs = 30
dcd_init = true

[cluster]
machines = 4
cores = 8
latency_us = 50.0
bandwidth_mbps = 500.0
mode = "scalar"

[monitor]
every = 2
out = "results/x.csv"
"#;
        let c = TrainConfig::from_toml(text).unwrap();
        assert_eq!(c.data.name, "kdda");
        assert_eq!(c.data.seed, 7);
        assert_eq!(c.model.loss, LossKind::Logistic);
        assert_eq!(c.model.lambda, 1e-5);
        assert_eq!(c.optim.epochs, 30);
        assert!(c.optim.dcd_init);
        assert_eq!(c.workers(), 32);
        assert_eq!(c.monitor.every, 2);
        assert_eq!(c.monitor.out, "results/x.csv");
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let c = TrainConfig::from_toml("[model]\nlambda = 0.001\n").unwrap();
        assert_eq!(c.model.lambda, 0.001);
        assert_eq!(c.data.name, "real-sim");
        assert_eq!(c.optim.algorithm, Algorithm::Dso);
    }

    #[test]
    fn rejects_invalid() {
        assert!(TrainConfig::from_toml("[model]\nlambda = 0\n").is_err());
        assert!(TrainConfig::from_toml("[model]\nloss = \"nope\"\n").is_err());
        assert!(TrainConfig::from_toml("[cluster]\nmachines = 0\n").is_err());
        assert!(TrainConfig::from_toml("[optim]\nepochs = 0\n").is_err());
        assert!(TrainConfig::from_toml("[data]\ntest_frac = 1.5\n").is_err());
    }

    #[test]
    fn enum_parsers() {
        assert_eq!(LossKind::parse("svm").unwrap(), LossKind::Hinge);
        assert_eq!(Algorithm::parse("bmrm").unwrap(), Algorithm::Bmrm);
        assert_eq!(StepKind::parse("invsqrt").unwrap(), StepKind::InvSqrt);
        assert_eq!(ExecMode::parse("tile").unwrap(), ExecMode::Tile);
        assert_eq!(ExecMode::parse("dso-proc").unwrap(), ExecMode::Proc);
        assert_eq!(ExecMode::parse("proc").unwrap(), ExecMode::Proc);
        for m in [ExecMode::Scalar, ExecMode::Tile, ExecMode::Proc] {
            assert_eq!(ExecMode::parse(m.name()).unwrap(), m);
        }
        assert!(RegKind::parse("l3").is_err());
        assert_eq!(SimdKind::parse("auto").unwrap(), SimdKind::Auto);
        assert_eq!(SimdKind::parse("portable").unwrap(), SimdKind::Portable);
        assert_eq!(SimdKind::parse("avx2").unwrap(), SimdKind::Avx2);
        assert_eq!(SimdKind::parse("avx512").unwrap(), SimdKind::Avx512);
        // name() and parse() are a round trip for every kind — the
        // supervisor pins a measured winner by emitting name() into the
        // worker config, so this is a wire-compat invariant.
        for k in [SimdKind::Auto, SimdKind::Portable, SimdKind::Avx2, SimdKind::Avx512] {
            assert_eq!(SimdKind::parse(k.name()).unwrap(), k);
        }
        let err = SimdKind::parse("sse9").unwrap_err();
        assert!(err.contains("avx512") && err.contains("portable"), "{err}");
    }

    #[test]
    fn simd_kind_parses_from_toml_and_validates_against_host() {
        let c = TrainConfig::from_toml("[cluster]\nsimd = \"portable\"\n").unwrap();
        assert_eq!(c.cluster.simd, SimdKind::Portable);
        assert_eq!(TrainConfig::default().cluster.simd, SimdKind::Auto);
        // Forcing a backend is valid exactly when the host supports it
        // — never a silent fallback. The refusal enumerates the
        // host-supported menu (simd::forced_unsupported_msg).
        let forced = TrainConfig::from_toml("[cluster]\nsimd = \"avx2\"\n");
        if crate::simd::avx2_supported() {
            assert_eq!(forced.unwrap().cluster.simd, SimdKind::Avx2);
        } else {
            let err = forced.unwrap_err();
            assert!(err.contains("avx2") && err.contains("portable"), "{err}");
        }
        let forced512 = TrainConfig::from_toml("[cluster]\nsimd = \"avx512\"\n");
        if crate::simd::avx512_supported() {
            assert_eq!(forced512.unwrap().cluster.simd, SimdKind::Avx512);
        } else {
            let err = forced512.unwrap_err();
            assert!(err.contains("avx512f+avx2+fma"), "{err}");
            assert!(err.contains("supported on this host"), "{err}");
        }
    }

    #[test]
    fn faults_validated_per_engine() {
        // Timing-only faults are fine on the sync engine.
        let c = TrainConfig::from_toml("[cluster]\nfaults = \"stall@0.1.0:20,delay@1.0.1:5\"\n")
            .unwrap();
        assert_eq!(c.cluster.faults, "stall@0.1.0:20,delay@1.0.1:5");
        // Death/drop faults need the async engine's recovery path.
        let err = TrainConfig::from_toml("[cluster]\nfaults = \"die@0.1.0\"\n").unwrap_err();
        assert!(err.contains("dso-async"), "{err}");
        let c = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nfaults = \"die@0.1.0\"\n",
        )
        .unwrap();
        assert_eq!(c.optim.algorithm, Algorithm::DsoAsync);
        // Non-DSO algorithms have no ring to fault.
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"sgd\"\n[cluster]\nfaults = \"stall@0.0.0\"\n",
        )
        .unwrap_err();
        assert!(err.contains("sgd"), "{err}");
        // Malformed specs are rejected at validation, not at run time.
        assert!(TrainConfig::from_toml("[cluster]\nfaults = \"zap@0.0.0\"\n").is_err());
    }

    #[test]
    fn proc_mode_validated() {
        // dso-proc needs the async engine's recovery machinery.
        let err = TrainConfig::from_toml("[cluster]\nmode = \"dso-proc\"\n").unwrap_err();
        assert!(err.contains("dso-async"), "{err}");
        let c = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nmode = \"dso-proc\"\n",
        )
        .unwrap();
        assert_eq!(c.cluster.mode, ExecMode::Proc);
        assert_eq!(c.cluster.heartbeat_ms, 50);
        assert_eq!(c.cluster.death_timeout_ms, 1500);
        // Timeout knobs parse and must be ordered sanely.
        let c = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nmode = \"dso-proc\"\n\
             heartbeat_ms = 20\ndeath_timeout_ms = 400\nsched_out = \"s.log\"\n",
        )
        .unwrap();
        assert_eq!(c.cluster.heartbeat_ms, 20);
        assert_eq!(c.cluster.death_timeout_ms, 400);
        assert_eq!(c.cluster.sched_out, "s.log");
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nmode = \"dso-proc\"\n\
             heartbeat_ms = 100\ndeath_timeout_ms = 100\n",
        )
        .unwrap_err();
        assert!(err.contains("exceed"), "{err}");
    }

    #[test]
    fn kill_and_partition_faults_need_proc_mode() {
        // kill@ is a real SIGKILL — meaningless for OS threads.
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nfaults = \"kill@0.1.0\"\n",
        )
        .unwrap_err();
        assert!(err.contains("dso-proc"), "{err}");
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nfaults = \"partition@0.1.0:40\"\n",
        )
        .unwrap_err();
        assert!(err.contains("dso-proc"), "{err}");
        let c = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[cluster]\nmode = \"dso-proc\"\n\
             faults = \"kill@0.1.0,partition@1.0.0:40\"\n",
        )
        .unwrap();
        assert_eq!(c.cluster.faults, "kill@0.1.0,partition@1.0.0:40");
    }

    #[test]
    fn checkpoint_config_validated() {
        let c = TrainConfig::from_toml("[checkpoint]\nevery = 2\npath = \"ck.txt\"\n").unwrap();
        assert_eq!(c.checkpoint.every, 2);
        assert_eq!(c.checkpoint.path, "ck.txt");
        assert!(TrainConfig::from_toml("[checkpoint]\nevery = 2\n").is_err());
        // Only the sync scalar DSO engine snapshots saddle state.
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"dso-async\"\n[checkpoint]\nevery = 1\npath = \"ck.txt\"\n",
        )
        .unwrap_err();
        assert!(err.contains("dso"), "{err}");
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"sgd\"\n[checkpoint]\nresume = \"ck.txt\"\n",
        )
        .unwrap_err();
        assert!(err.contains("scalar"), "{err}");
    }

    #[test]
    fn cache_config_validated() {
        // Every mode name round-trips, plus the aliases.
        for m in [CacheMode::Off, CacheMode::Build, CacheMode::Use, CacheMode::Auto] {
            assert_eq!(CacheMode::parse(m.name()).unwrap(), m);
        }
        assert_eq!(CacheMode::parse("mmap").unwrap(), CacheMode::Use);
        assert_eq!(CacheMode::parse("pack").unwrap(), CacheMode::Build);
        assert!(CacheMode::parse("sometimes").is_err());
        // cache != off requires a cache_dir.
        let err = TrainConfig::from_toml("[cluster]\ncache = \"use\"\n").unwrap_err();
        assert!(err.contains("cache_dir"), "{err}");
        let c = TrainConfig::from_toml(
            "[cluster]\ncache = \"auto\"\ncache_dir = \"/tmp/dso-cache\"\n",
        )
        .unwrap();
        assert_eq!(c.cluster.cache, CacheMode::Auto);
        assert_eq!(c.cluster.cache_dir, "/tmp/dso-cache");
        // Only the DSO engines pack blocks.
        let err = TrainConfig::from_toml(
            "[optim]\nalgorithm = \"sgd\"\n[cluster]\ncache = \"build\"\ncache_dir = \"c\"\n",
        )
        .unwrap_err();
        assert!(err.contains("sgd"), "{err}");
        // The tile engine reads dense sub-tiles, not packed blocks.
        let err = TrainConfig::from_toml(
            "[cluster]\nmode = \"tile\"\ncache = \"use\"\ncache_dir = \"c\"\n",
        )
        .unwrap_err();
        assert!(err.contains("tile"), "{err}");
        // Defaults stay off.
        assert_eq!(TrainConfig::default().cluster.cache, CacheMode::Off);
    }

    #[test]
    fn loss_names_roundtrip() {
        for l in [LossKind::Hinge, LossKind::Logistic, LossKind::Square] {
            assert_eq!(LossKind::parse(l.name()).unwrap(), l);
        }
    }
}
