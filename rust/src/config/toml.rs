//! TOML-subset parser.
//!
//! serde/toml are not in the offline crate set. DSO's config files need
//! tables, key = value with strings / ints / floats / bools, and flat
//! arrays — this module implements exactly that subset with good error
//! messages (line numbers), and nothing more.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`lambda = 1` works).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: `table.key -> Value`. Keys in the root table have
/// no prefix; `[section]` prefixes subsequent keys with `section.`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error, line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line: line_no,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(TomlError { line: line_no, msg: "empty section name".into() });
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or(TomlError {
                line: line_no,
                msg: format!("expected 'key = value', got '{line}'"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(TomlError { line: line_no, msg: "empty key".into() });
            }
            let full_key =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value = parse_value(val.trim(), line_no)?;
            if entries.insert(full_key.clone(), value).is_some() {
                return Err(TomlError {
                    line: line_no,
                    msg: format!("duplicate key '{full_key}'"),
                });
            }
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(|v| v.as_i64())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// Keys of a section (unprefixed part).
    pub fn section_keys<'a>(&'a self, section: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let prefix = format!("{section}.");
        self.entries.keys().filter_map(move |k| k.strip_prefix(prefix.as_str()))
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, TomlError> {
    let err = |msg: String| TomlError { line, msg };
    if s.is_empty() {
        return Err(err("empty value".into()));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| err("unterminated string".into()))?;
        // Basic escape handling.
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(err(format!("bad escape '\\{other:?}'"))),
                }
            } else if c == '"' {
                return Err(err("unescaped quote inside string".into()));
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value '{s}'")))
}

/// Split a flat array body on commas that are not inside strings.
fn split_array_items(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = Doc::parse(
            "a = 1\nb = -2.5\nc = \"hi\"\nd = true\ne = false\nf = 1e-4\ng = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get_i64("a"), Some(1));
        assert_eq!(doc.get_f64("b"), Some(-2.5));
        assert_eq!(doc.get_str("c"), Some("hi"));
        assert_eq!(doc.get_bool("d"), Some(true));
        assert_eq!(doc.get_bool("e"), Some(false));
        assert_eq!(doc.get_f64("f"), Some(1e-4));
        assert_eq!(doc.get_i64("g"), Some(1000));
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = Doc::parse("lambda = 1\n").unwrap();
        assert_eq!(doc.get_f64("lambda"), Some(1.0));
    }

    #[test]
    fn sections_prefix_keys() {
        let doc = Doc::parse("x = 1\n[optim]\neta = 0.5\n[data]\nname = \"ocr\"\n").unwrap();
        assert_eq!(doc.get_i64("x"), Some(1));
        assert_eq!(doc.get_f64("optim.eta"), Some(0.5));
        assert_eq!(doc.get_str("data.name"), Some("ocr"));
        let keys: Vec<&str> = doc.section_keys("optim").collect();
        assert_eq!(keys, vec!["eta"]);
    }

    #[test]
    fn comments_stripped() {
        let doc = Doc::parse("# full line\na = 1 # trailing\nb = \"x # not a comment\"\n").unwrap();
        assert_eq!(doc.get_i64("a"), Some(1));
        assert_eq!(doc.get_str("b"), Some("x # not a comment"));
    }

    #[test]
    fn arrays() {
        let doc = Doc::parse("xs = [1, 2, 3]\nys = [1.5, \"a,b\", true]\nempty = []\n").unwrap();
        let xs = doc.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_i64(), Some(3));
        let ys = doc.get("ys").unwrap().as_array().unwrap();
        assert_eq!(ys[1].as_str(), Some("a,b"));
        assert_eq!(ys[2].as_bool(), Some(true));
        assert_eq!(doc.get("empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn string_escapes() {
        let doc = Doc::parse("s = \"a\\nb\\t\\\"q\\\"\"\n").unwrap();
        assert_eq!(doc.get_str("s"), Some("a\nb\t\"q\""));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Doc::parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Doc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Doc::parse("a = \n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(Doc::parse("a = 1\na = 2\n").is_err());
        // Same key in different sections is fine.
        assert!(Doc::parse("[x]\na = 1\n[y]\na = 2\n").is_ok());
    }

    #[test]
    fn rejects_bad_values() {
        for s in ["a = zzz", "a = \"open", "a = [1, 2", "a = 1.2.3"] {
            assert!(Doc::parse(s).is_err(), "{s}");
        }
    }
}
