//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline crate set has no crates.io access, so this shim provides
//! exactly the API surface the `dso` crate uses: [`Error`], [`Result`],
//! [`Error::msg`], and the `anyhow!` / `bail!` / `ensure!` macros. The
//! error is a flat message (no backtrace / cause chain); `?` works on
//! any `std::error::Error + Send + Sync + 'static` source via the same
//! blanket `From` impl real anyhow uses.

use std::fmt;

/// A flat, message-carrying error type.
pub struct Error {
    msg: String,
}

/// `Result<T, anyhow::Error>` with the error type defaulted, exactly
/// like real anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes this blanket impl coherent (same trick as real
// anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrips_display_and_debug() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io boom"))?;
            Ok(())
        }
        assert!(io_fail().unwrap_err().to_string().contains("io boom"));
    }

    #[test]
    fn macros_compose() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 1 {
                bail!("one is not allowed");
            }
            Err(anyhow!("fallthrough {}", x))
        }
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(1).unwrap_err().to_string().contains("one"));
        assert!(f(2).unwrap_err().to_string().contains("fallthrough 2"));
    }
}
