//! DSO scaling study (Figure 5 workload): machines ∈ {1, 2, 4, 8},
//! fixed cores per machine, on the sparse kdda analog and the dense
//! ocr analog. Prints virtual-time speedups and the objective reached.
//!
//! Run: `cargo run --release --example scaling [scale]`

use dso::api::Trainer;
use dso::config::{Algorithm, TrainConfig};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.4);
    for dataset in ["kdda", "ocr"] {
        let ds =
            dso::data::registry::generate(dataset, scale, 3).map_err(anyhow::Error::msg)?;
        let (train, _) = ds.split(0.2, 3);
        println!(
            "\n=== {dataset} analog: m={} d={} nnz={} ===",
            train.m(),
            train.d(),
            train.nnz()
        );
        println!(
            "{:>9} {:>9} {:>12} {:>11} {:>9} {:>10}",
            "machines", "workers", "objective", "virtual_s", "speedup", "comm_MB"
        );
        let mut base = None;
        for machines in [1usize, 2, 4, 8] {
            let mut cfg = TrainConfig::default();
            cfg.optim.epochs = 20;
            cfg.optim.eta0 = 0.1;
            cfg.model.lambda = 1e-4;
            cfg.cluster.machines = machines;
            cfg.cluster.cores = 4;
            cfg.monitor.every = 0;
            let r = Trainer::new(cfg)
                .algorithm(Algorithm::Dso)
                .fit(&train, None)?
                .into_result();
            let speedup = match base {
                None => {
                    base = Some(r.total_virtual_s);
                    1.0
                }
                Some(b) => b / r.total_virtual_s,
            };
            println!(
                "{:>9} {:>9} {:>12.6} {:>11.4} {:>9.2} {:>10.2}",
                machines,
                machines * 4,
                r.final_primal,
                r.total_virtual_s,
                speedup,
                r.comm_bytes as f64 / 1e6
            );
        }
    }
    Ok(())
}
