//! Quickstart: train a linear SVM with DSO on a synthetic real-sim-like
//! dataset, on a simulated 2-machine × 2-core cluster, through the
//! `dso::api::Trainer` facade — with live per-epoch streaming via an
//! `EpochObserver` closure.
//!
//! Run: `cargo run --release --example quickstart`

use dso::api::Trainer;
use dso::config::{Algorithm, TrainConfig};
use dso::coordinator::EvalRow;

fn main() -> anyhow::Result<()> {
    // 1. A dataset from the Table 2 registry (scaled down; see
    //    `dso::data::registry` for all nine paper datasets).
    let ds = dso::data::registry::generate("real-sim", 0.5, 42).map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, 42);
    println!("dataset: m={} d={} nnz={}", train.m(), train.d(), train.nnz());

    // 2. Configure DSO (Algorithm 1): hinge loss, L2, AdaGrad steps.
    let mut cfg = TrainConfig::default();
    cfg.optim.epochs = 40;
    cfg.optim.eta0 = 0.1;
    cfg.model.lambda = 1e-4;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 2;
    cfg.monitor.every = 5;

    // 3. Train through the facade, streaming each evaluation as it
    //    happens (what `Monitor` used to keep internal until the end).
    let mut on_epoch = |row: &EvalRow| {
        println!(
            "  epoch {:>3}: objective {:.6}  gap {:.3e}  test_err {:.4}",
            row.epoch, row.primal, row.gap, row.test_error
        );
    };
    let fitted = Trainer::new(cfg)
        .algorithm(Algorithm::Dso)
        .observer(&mut on_epoch)
        .fit(&train, Some(&test))?;

    // 4. Inspect the fitted artifact: objective, duality gap
    //    (Theorem 1's measure), errors, predictions.
    let result = &fitted.result;
    println!(
        "\nfinal: objective={:.6}  duality gap={:.3e}  test error={:.4}",
        result.final_primal,
        result.final_gap,
        fitted.error(&test),
    );
    println!(
        "ran {} scalar saddle updates in {:.3}s simulated cluster time ({:.1} MB moved)",
        result.total_updates,
        result.total_virtual_s,
        result.comm_bytes as f64 / 1e6
    );

    // 5. Persist the model (libsvm-style text) and predict.
    let model_path = std::env::temp_dir().join("quickstart.dso-model");
    fitted.save(&model_path)?;
    let margins = fitted.predict(&test.x)?;
    println!(
        "saved model to {} ({} weights); first test margin {:.4}",
        model_path.display(),
        fitted.w().len(),
        margins.first().copied().unwrap_or(f64::NAN)
    );
    Ok(())
}
