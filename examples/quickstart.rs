//! Quickstart: train a linear SVM with DSO on a synthetic real-sim-like
//! dataset, on a simulated 2-machine × 2-core cluster.
//!
//! Run: `cargo run --release --example quickstart`

use dso::config::{Algorithm, TrainConfig};

fn main() -> anyhow::Result<()> {
    // 1. A dataset from the Table 2 registry (scaled down; see
    //    `dso::data::registry` for all nine paper datasets).
    let ds = dso::data::registry::generate("real-sim", 0.5, 42).map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, 42);
    println!("dataset: m={} d={} nnz={}", train.m(), train.d(), train.nnz());

    // 2. Configure DSO (Algorithm 1): hinge loss, L2, AdaGrad steps.
    let mut cfg = TrainConfig::default();
    cfg.optim.algorithm = Algorithm::Dso;
    cfg.optim.epochs = 40;
    cfg.optim.eta0 = 0.1;
    cfg.model.lambda = 1e-4;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 2;
    cfg.monitor.every = 5;

    // 3. Train.
    let result = dso::coordinator::train(&cfg, &train, Some(&test))?;

    // 4. Inspect: objective, duality gap (Theorem 1's measure), errors.
    println!("\nepoch history:");
    println!("{}", result.history.render(20));
    println!(
        "final: objective={:.6}  duality gap={:.3e}  test error={:.4}",
        result.final_primal,
        result.final_gap,
        result.history.col("test_error").and_then(|c| c.last().copied()).unwrap_or(f64::NAN),
    );
    println!(
        "ran {} scalar saddle updates in {:.3}s simulated cluster time ({:.1} MB moved)",
        result.total_updates,
        result.total_virtual_s,
        result.comm_bytes as f64 / 1e6
    );
    Ok(())
}
