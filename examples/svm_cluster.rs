//! Multi-machine comparison on the kdda analog (Figure 3 workload):
//! DSO vs BMRM vs PSGD on a simulated 4-machine × 4-core cluster,
//! all three routed through the same `dso::api::Trainer` facade.
//!
//! Run: `cargo run --release --example svm_cluster [scale]`

use dso::api::Trainer;
use dso::config::{Algorithm, TrainConfig};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let ds = dso::data::registry::generate("kdda", scale, 11).map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, 11);
    println!(
        "kdda analog @ scale {scale}: m={} d={} nnz={}",
        train.m(),
        train.d(),
        train.nnz()
    );

    let mut results = Vec::new();
    for algo in [Algorithm::Dso, Algorithm::Bmrm, Algorithm::Psgd] {
        let mut cfg = TrainConfig::default();
        cfg.optim.epochs = 30;
        cfg.optim.eta0 = 0.1;
        cfg.optim.dcd_init = algo == Algorithm::Dso;
        cfg.model.lambda = 1e-4;
        cfg.cluster.machines = 4;
        cfg.cluster.cores = 4;
        cfg.monitor.every = 1;
        let r = Trainer::new(cfg).algorithm(algo).fit(&train, Some(&test))?.into_result();
        println!(
            "{:>5}: objective={:.6} gap={:>10.3e} virtual={:.3}s comm={:.2}MB",
            r.algorithm,
            r.final_primal,
            r.final_gap,
            r.total_virtual_s,
            r.comm_bytes as f64 / 1e6
        );
        results.push(r);
    }

    // Convergence traces side by side (objective per epoch).
    println!("\nobjective by epoch:");
    println!("{:>6} {:>12} {:>12} {:>12}", "epoch", "dso", "bmrm", "psgd");
    let cols: Vec<Vec<f64>> =
        results.iter().map(|r| r.history.col("primal").unwrap()).collect();
    let epochs: Vec<f64> = results[0].history.col("epoch").unwrap();
    for k in 0..epochs.len().min(cols.iter().map(|c| c.len()).min().unwrap_or(0)) {
        println!(
            "{:>6} {:>12.6} {:>12.6} {:>12.6}",
            epochs[k], cols[0][k], cols[1][k], cols[2][k]
        );
    }
    Ok(())
}
