//! Logistic regression on the dense ocr analog through the tile/PJRT
//! path: the AOT-compiled Pallas kernel executes every block update.
//! Falls back to the scalar engine when artifacts are missing.
//!
//! Run: `make artifacts && cargo run --release --example logistic_dense`

use dso::api::Trainer;
use dso::config::{ExecMode, LossKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    let ds = dso::data::registry::generate("ocr", 0.4, 5).map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, 5);
    println!("ocr analog: m={} d={} (dense)", train.m(), train.d());

    let have_artifacts = dso::runtime::Manifest::load_default().is_ok();
    let mut cfg = TrainConfig::default();
    cfg.model.loss = LossKind::Logistic;
    cfg.model.lambda = 1e-4;
    cfg.optim.epochs = 50;
    cfg.optim.eta0 = 0.3;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 2;
    cfg.monitor.every = 5;
    let mode = if have_artifacts { ExecMode::Tile } else { ExecMode::Scalar };
    println!(
        "mode: {}",
        if have_artifacts { "tile (Pallas kernel via PJRT)" } else { "scalar (run `make artifacts`)" }
    );

    let fitted = Trainer::new(cfg).mode(mode).fit(&train, Some(&test))?;
    let r = &fitted.result;
    println!("\n{}", r.history.render(20));
    println!(
        "final objective {:.6}, gap {:.3e}, test error {:.4}",
        r.final_primal,
        r.final_gap,
        fitted.error(&test)
    );
    Ok(())
}
