//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises the full three-layer stack on a real small workload
//! through the `dso::api::Trainer` facade:
//!   * generates the real-sim analog dataset (~5.8k × 2.1k sparse),
//!   * trains linear SVM with DSO on a simulated 4-machine × 2-core
//!     cluster for 150 epochs, logging the full convergence curve,
//!   * cross-checks the final objective against an independent
//!     high-accuracy solver (BMRM, plus the DCD reference),
//!   * if AOT artifacts are present, additionally trains the dense ocr
//!     analog through the tile/PJRT path (Pallas kernel execution),
//!   * writes results/e2e/*.csv and prints the loss curve.
//!
//! Run: `cargo run --release --example e2e_train`

use dso::api::Trainer;
use dso::config::{Algorithm, ExecMode, TrainConfig};
use dso::losses::{Loss, Problem, Regularizer};

fn main() -> anyhow::Result<()> {
    let out = std::path::Path::new("results/e2e");
    std::fs::create_dir_all(out)?;
    let lambda = 1e-4;

    // ---------- sparse path: scalar DSO on real-sim ----------
    let ds = dso::data::registry::generate("real-sim", 1.0, 7).map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, 7);
    println!(
        "[e2e] real-sim analog: m={} d={} nnz={} (density {:.3}%)",
        train.m(),
        train.d(),
        train.nnz(),
        100.0 * train.x.density()
    );

    let mut cfg = TrainConfig::default();
    cfg.optim.epochs = 150;
    cfg.optim.eta0 = 0.1;
    cfg.model.lambda = lambda;
    cfg.cluster.machines = 4;
    cfg.cluster.cores = 2;
    cfg.monitor.every = 1;

    let dso_f = Trainer::new(cfg.clone()).algorithm(Algorithm::Dso).fit(&train, Some(&test))?;
    let dso_r = &dso_f.result;
    dso_r.history.write_csv(&out.join("dso_realsim.csv"))?;

    // Reference optimum: BMRM run to tight gap + DCD solver.
    let mut bcfg = cfg.clone();
    bcfg.optim.epochs = 300;
    let bmrm_r = Trainer::new(bcfg)
        .algorithm(Algorithm::Bmrm)
        .fit(&train, Some(&test))?
        .into_result();
    bmrm_r.history.write_csv(&out.join("bmrm_realsim.csv"))?;
    let dcd = dso::optim::dcd::solve_hinge_l2(&train, lambda, 2000, 1e-10, 1);
    let problem = Problem::new(Loss::Hinge, Regularizer::L2, lambda);
    let p_star = problem.primal(&train, &dcd.w).min(bmrm_r.final_primal);

    println!("\n[e2e] loss curve (every 10 epochs):");
    println!("{:>6} {:>12} {:>12} {:>10}", "epoch", "objective", "gap", "test_err");
    for row in dso_r.history.rows.iter().step_by(10) {
        println!("{:>6} {:>12.6} {:>12.4e} {:>10.4}", row[0], row[3], row[5], row[6]);
    }

    let rel = (dso_r.final_primal - p_star) / p_star.abs().max(1e-12);
    println!(
        "\n[e2e] DSO objective {:.6} vs reference optimum {:.6} (rel excess {:.3}%)",
        dso_r.final_primal,
        p_star,
        100.0 * rel
    );
    println!(
        "[e2e] duality gap {:.3e}; test error {:.4}; {:.1} MB communicated",
        dso_r.final_gap,
        dso_f.error(&test),
        dso_r.comm_bytes as f64 / 1e6
    );
    anyhow::ensure!(rel < 0.05, "DSO did not reach within 5% of the optimum");
    anyhow::ensure!(dso_r.final_gap >= -1e-6, "weak duality violated");

    // Model persistence round trip on the real run.
    let model_path = out.join("dso_realsim.model");
    dso_f.save(&model_path)?;
    let loaded = dso::api::Model::load(&model_path)?;
    anyhow::ensure!(loaded.w == dso_f.w(), "model save/load changed w");
    println!("[e2e] model round trip OK ({} weights)", loaded.w.len());

    // ---------- dense path: tile DSO through PJRT ----------
    match dso::runtime::Manifest::load_default() {
        Err(e) => println!("\n[e2e] tile path skipped (no artifacts: {e})"),
        Ok(_) => {
            let dense =
                dso::data::registry::generate("ocr", 0.3, 7).map_err(anyhow::Error::msg)?;
            let (dtrain, dtest) = dense.split(0.2, 7);
            let mut tcfg = TrainConfig::default();
            tcfg.optim.epochs = 40;
            tcfg.optim.eta0 = 0.3;
            tcfg.model.lambda = lambda;
            tcfg.cluster.machines = 2;
            tcfg.cluster.cores = 2;
            tcfg.monitor.every = 2;
            let tile_r = Trainer::new(tcfg)
                .algorithm(Algorithm::Dso)
                .mode(ExecMode::Tile)
                .fit(&dtrain, Some(&dtest))?
                .into_result();
            tile_r.history.write_csv(&out.join("dso_tile_ocr.csv"))?;
            let at_zero = Problem::new(Loss::Hinge, Regularizer::L2, lambda)
                .primal(&dtrain, &vec![0.0; dtrain.d()]);
            println!(
                "\n[e2e] tile/PJRT on ocr analog: objective {:.6} (P(0)={:.6}), gap {:.3e}",
                tile_r.final_primal, at_zero, tile_r.final_gap
            );
            anyhow::ensure!(tile_r.final_primal < 0.8 * at_zero, "tile path failed to learn");
        }
    }

    println!("\n[e2e] OK — curves in {}", out.display());
    Ok(())
}
