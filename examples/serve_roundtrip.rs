//! Serving round trip: train → save → serve → predict over the wire →
//! warm-start retrain (`Trainer::fit_from`) → hot reload → predict with
//! the updated model → stats → shutdown.
//!
//! This is the client side of `dso serve` (DESIGN.md §Serving), driven
//! in-process: the server runs on a thread, the client speaks the same
//! framed transport (`FrameConn`) the multi-process trainer uses.
//!
//! Run: `cargo run --release --example serve_roundtrip`

use dso::api::Trainer;
use dso::config::{Algorithm, TrainConfig};
use dso::data::{libsvm, Dataset};
use dso::net::transport::{connect_with_backoff, ConnIn, FrameConn};
use dso::net::wire::Msg;
use dso::serve::{NullServeObserver, ServeOptions, Server};
use std::time::Duration;

fn recv_msg(conn: &mut FrameConn) -> anyhow::Result<Msg> {
    loop {
        match conn.recv()? {
            ConnIn::Msg(m) => return Ok(m),
            ConnIn::TimedOut => continue,
            other => anyhow::bail!("connection dropped mid-reply: {other:?}"),
        }
    }
}

fn main() -> anyhow::Result<()> {
    // 1. Train a small model and persist it.
    let ds = dso::data::registry::generate("real-sim", 0.1, 42).map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, 42);
    let mut cfg = TrainConfig::default();
    cfg.optim.epochs = 10;
    cfg.optim.eta0 = 0.1;
    cfg.model.lambda = 1e-4;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 2;
    let fitted = Trainer::new(cfg.clone()).algorithm(Algorithm::Dso).fit(&train, Some(&test))?;
    let dir = std::env::temp_dir().join("dso-serve-roundtrip");
    std::fs::create_dir_all(&dir)?;
    let model_v1 = dir.join("model-v1.dso");
    fitted.save(&model_v1)?;
    println!("trained v1: d={} test_err={:.4}", fitted.w().len(), fitted.error(&test));

    // 2. Stand the server up on a background thread.
    let socket = dir.join("serve.sock");
    let mut server = Server::bind(&ServeOptions::new(&model_v1, &socket))?;
    println!("serving on {} (backend {})", socket.display(), server.backend());
    let handle = std::thread::spawn(move || server.run(&mut NullServeObserver));

    // 3. Dial it and score the first 16 test rows. The batch is plain
    //    libsvm text — what any non-Rust client would send.
    let mut conn = FrameConn::new(connect_with_backoff(&socket, Duration::from_secs(5))?);
    conn.set_recv_timeout(Some(Duration::from_millis(200)))?;
    let rows: Vec<usize> = (0..16.min(test.m())).collect();
    let batch = libsvm::emit(&Dataset::new(
        "batch",
        test.x.select_rows(&rows),
        rows.iter().map(|&i| test.y[i]).collect(),
    ));
    conn.send(&Msg::Predict { id: 1, batch: batch.clone() })?;
    let Msg::Scores { scores: v1, .. } = recv_msg(&mut conn)? else {
        anyhow::bail!("expected Scores for request 1");
    };
    // The server's batched SIMD kernel reproduces the local scalar
    // predict bit-for-bit (pinned in rust/tests/serve.rs).
    let local = fitted.predict(&test.x.select_rows(&rows))?;
    assert_eq!(v1, local, "wire scores must match local predict exactly");
    println!("request 1: {} scores, first margin {:+.4}", v1.len(), v1[0]);

    // 4. Warm-start retrain from the fitted prior (same data, more
    //    epochs — appended rows/features work the same way), save v2.
    let mut cfg2 = cfg;
    cfg2.optim.epochs = 30;
    let refit = Trainer::new(cfg2).algorithm(Algorithm::Dso).fit_from(&fitted, &train, Some(&test))?;
    let model_v2 = dir.join("model-v2.dso");
    refit.save(&model_v2)?;
    println!("warm-start retrained v2: test_err={:.4}", refit.error(&test));

    // 5. Hot reload, then score the same batch with the new weights.
    conn.send(&Msg::Reload { path: model_v2.display().to_string() })?;
    anyhow::ensure!(matches!(recv_msg(&mut conn)?, Msg::Ack { .. }), "reload not acked");
    conn.send(&Msg::Predict { id: 2, batch })?;
    let Msg::Scores { scores: v2, .. } = recv_msg(&mut conn)? else {
        anyhow::bail!("expected Scores for request 2");
    };
    assert_eq!(v2, refit.predict(&test.x.select_rows(&rows))?);
    println!("request 2 (reloaded): first margin {:+.4} (was {:+.4})", v2[0], v1[0]);

    // 6. Counters, then a clean shutdown.
    conn.send(&Msg::StatsReq)?;
    if let Msg::StatsReply { served, rows, reloads, backend, .. } = recv_msg(&mut conn)? {
        println!("server stats: served={served} rows={rows} reloads={reloads} backend={backend}");
    }
    conn.send(&Msg::Shutdown)?;
    anyhow::ensure!(matches!(recv_msg(&mut conn)?, Msg::Bye), "no Bye on shutdown");
    handle.join().expect("server thread")?;
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
