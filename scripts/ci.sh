#!/usr/bin/env bash
# CI gate: format, lint, tests, and a quick-mode bench smoke that also
# records BENCH_updates.json, BENCH_lanes.json, BENCH_alpha_lanes.json,
# BENCH_simd.json, BENCH_autotune.json, BENCH_faults.json,
# BENCH_transport.json and BENCH_outofcore.json (the cross-PR perf
# trajectory; plot with `python scripts/plot_results.py --bench`).
#
# Usage: scripts/ci.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH" >&2
    exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings -D deprecated =="
# -D deprecated keeps the build warning-clean against the Trainer-era
# shims: internal code must use the facade; only the suites that pin
# shim-vs-facade bit-identity opt back in via #[allow(deprecated)].
cargo clippy --workspace --all-targets -- -D warnings -D deprecated

echo "== kernel dispatch and feature detection live only in SweepPlan/setup =="
# PR 4 moved the has_lanes()/affine_alpha() kernel-selection tree out
# of the engines into rust/src/coordinator/plan.rs; PR 5 added the
# SIMD-backend dimension, resolved once per run (is_x86_feature_detected
# in rust/src/simd/, recorded by DsoSetup into the plan). If either
# decision leaks back into an engine, fail loudly: it is exactly the
# copy-paste drift these gates exist to prevent.
if grep -n "has_lanes\|affine_alpha\|is_x86_feature_detected" \
    rust/src/coordinator/engine.rs \
    rust/src/coordinator/async_engine.rs \
    rust/src/runtime/tile_engine.rs; then
    echo "ci.sh: kernel/backend selection leaked back into an engine;" \
         "dispatch belongs in rust/src/coordinator/plan.rs," \
         "detection in rust/src/simd/" >&2
    exit 1
fi

echo "== serving layer performs no feature detection =="
# The server resolves its SIMD backend exactly once, in Server::bind,
# through simd::resolve — the same single detection site the engines
# use. Detection leaking into the serve modules (or the api facade's
# predict path) would fork the backend decision per batch.
if grep -n "is_x86_feature_detected" rust/src/serve/*.rs rust/src/api.rs; then
    echo "ci.sh: feature detection leaked into the serving layer;" \
         "resolve a SimdLevel once via rust/src/simd/ and pass it down" >&2
    exit 1
fi

echo "== AVX-512 intrinsics and detection confined to rust/src/simd/ =="
# The 512-bit intrinsics (and every _mm512/__m512 type) live behind the
# SimdBackend trait like the AVX2 set: kernels, engines, serving and
# benches reach them only through monomorphized backends or the fused
# #[target_feature] entry points. Comment lines are exempt.
if grep -rn "_mm512\|__m512\|__mmask" rust/src --include="*.rs" \
    | grep -v "^rust/src/simd/" \
    | grep -v ":[[:space:]]*//"; then
    echo "ci.sh: AVX-512 intrinsics leaked outside rust/src/simd/;" \
         "add an op to SimdBackend instead" >&2
    exit 1
fi

echo "== every unsafe block in simd/ and updates.rs carries a SAFETY comment =="
# The explicit-SIMD layer concentrates the repo's unsafe code; each
# `unsafe {` block must be annotated with the argument that makes it
# sound (a `// SAFETY:` line within the preceding dozen lines — wide
# enough for a real soundness argument, narrow enough that a comment
# cannot cover an unrelated block).
unsafe_gate() {
    awk '
        /SAFETY:/ { cover = 12 }
        # Only code lines count as unsafe blocks — a comment *about*
        # unsafe blocks must not trip the gate.
        /unsafe[[:space:]]*\{/ && $0 !~ /^[[:space:]]*\/\// {
            if (cover <= 0) {
                printf "%s:%d: unsafe block without a preceding // SAFETY: comment\n", FILENAME, FNR
                bad = 1
            }
        }
        { if (cover > 0) cover-- }
        END { exit bad }
    ' "$1"
}
for f in rust/src/simd/*.rs rust/src/coordinator/updates.rs rust/src/data/cache/*.rs \
    rust/src/serve/*.rs; do
    if ! unsafe_gate "$f"; then
        echo "ci.sh: annotate the unsafe block(s) above in $f" >&2
        exit 1
    fi
done

echo "== cargo build --examples =="
# The six examples are the facade's public face; they must always
# compile against the current dso::api::Trainer surface.
cargo build --examples

echo "== lane kernel property suite present =="
# The SIMD sweep's correctness story rests on tests/lane_kernel.rs; if
# the suite is ever renamed, filtered out, or deleted, fail loudly
# instead of letting `cargo test` pass without it.
lane_required=(prop_lanes_match_scalar_oracle prop_sentinel_padding_never_perturbs_state
    lanes_match_oracle_all_combinations_with_ragged_tails)
if [[ "$(uname -m)" == "x86_64" ]]; then
    # The AVX2-vs-portable differential suite compiles on every x86_64
    # build (it self-skips at runtime where avx2+fma is absent).
    lane_required+=(prop_avx2_matches_portable_and_oracle
        prop_avx2_sentinel_padding_inert
        fused_avx2_entry_points_match_generic_bitwise
        engine_threaded_equals_replay_under_avx2
        prop_avx512_matches_portable_and_oracle
        prop_avx512_sentinel_padding_inert
        avx512_is_bitwise_avx2_including_odd_chunk_epilogue
        fused_avx512_entry_points_match_generic_bitwise
        engine_threaded_equals_replay_under_avx512)
fi
# The measured-auto pins and the machine-independent pair-loop tests
# run on every architecture (no feature guard).
lane_required+=(auto_resolution_is_stable_and_recorded_on_the_plan
    forced_levels_refuse_rather_than_degrade)
lane_tests="$(cargo test -q --test lane_kernel -- --list 2>/dev/null || true)"
for required in "${lane_required[@]}"; do
    if ! grep -q "$required" <<<"$lane_tests"; then
        echo "ci.sh: lane kernel property test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== affine α-lane differential suite present =="
# Same guard for the square-loss affine-α path (tests/alpha_lane.rs):
# its tolerance-equivalence story rests on the differential suite.
alpha_required=(prop_affine_matches_coo_oracle prop_affine_sentinel_mutation_inert
    affine_matches_oracle_ragged_and_short_groups
    affine_long_row_stays_within_tolerance
    affine_entry_point_is_bitwise_lane_kernel_for_nonaffine_losses
    engine_affine_dispatch_threaded_equals_replay)
if [[ "$(uname -m)" == "x86_64" ]]; then
    alpha_required+=(prop_avx2_affine_matches_portable_and_oracle
        engine_avx2_affine_dispatch_threaded_equals_replay
        prop_avx512_affine_matches_portable_and_oracle
        avx512_affine_sweep_is_bitwise_avx2
        avx512_affine_entry_point_degrades_for_nonaffine_losses
        engine_avx512_affine_dispatch_threaded_equals_replay)
fi
alpha_tests="$(cargo test -q --test alpha_lane -- --list 2>/dev/null || true)"
for required in "${alpha_required[@]}"; do
    if ! grep -q "$required" <<<"$alpha_tests"; then
        echo "ci.sh: affine α-lane test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== chaos / fault-injection suite present =="
# ISSUE 6's acceptance rests on tests/chaos.rs: injected death at p = 4
# recovers and reports, crash-and-resume is bit-identical, timing
# faults never move the sync trajectory. Same renamed/filtered-out
# guard as the kernel suites above.
chaos_required=(chaos_async_death_is_recovered_and_reported
    chaos_checkpoint_resume_matches_uninterrupted_bitwise
    chaos_sync_timing_faults_preserve_bit_identity
    chaos_straggler_wait_time_surfaces_in_history)
chaos_tests="$(cargo test -q --test chaos -- --list 2>/dev/null || true)"
for required in "${chaos_required[@]}"; do
    if ! grep -q "$required" <<<"$chaos_tests"; then
        echo "ci.sh: chaos test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== transport chaos suite present =="
# ISSUE 7's acceptance rests on tests/transport_chaos.rs: the
# multi-process ring survives a real SIGKILL inside the objective band,
# a recorded schedule replays serially to bit-identical (w, α), and a
# fingerprint-skewed worker is refused at the handshake.
transport_required=(proc_clean_run_matches_thread_ring_band
    proc_sigkill_degrades_and_converges_in_band
    proc_injected_death_recovers_gracefully
    proc_partition_reconnects_and_stragglers_survive
    proc_recorded_schedule_replays_bit_identically
    proc_refuses_fingerprint_skewed_worker
    proc_mode_validation_is_actionable)
transport_tests="$(cargo test -q --test transport_chaos -- --list 2>/dev/null || true)"
for required in "${transport_required[@]}"; do
    if ! grep -q "$required" <<<"$transport_tests"; then
        echo "ci.sh: transport chaos test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== out-of-core cache suite present =="
# ISSUE 8's acceptance rests on tests/outofcore.rs: the .dsoblk
# pack/open round trip preserves every table (alignment included), a
# `--cache use` fit is bit-identical to the resident fit on both
# engines, a foreign-fingerprint cache is refused, and auto reuses
# without rewriting.
outofcore_required=(cache_roundtrip_preserves_every_table
    mapped_fit_matches_resident_bitwise_sync
    mapped_fit_matches_resident_bitwise_async
    foreign_fingerprint_cache_is_refused
    auto_cache_builds_then_reuses)
outofcore_tests="$(cargo test -q --test outofcore -- --list 2>/dev/null || true)"
for required in "${outofcore_required[@]}"; do
    if ! grep -q "$required" <<<"$outofcore_tests"; then
        echo "ci.sh: out-of-core test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== serving suite present =="
# ISSUE 9's acceptance rests on tests/serve.rs: the batched kernel is
# bit-identical to the old scalar predict, Auto routing moves no bits,
# and the end-to-end server round trip (predict → warm-start reload →
# stats → shutdown) holds over the framed transport.
serve_required=(batched_predict_is_bitwise_identical_to_scalar_predict
    auto_backend_matches_portable_bitwise
    server_roundtrip_predict_reload_stats_shutdown
    measured_auto_server_reports_its_selection)
if [[ "$(uname -m)" == "x86_64" ]]; then
    serve_required+=(avx2_batch_predict_stays_within_tolerance
        avx512_batch_predict_is_bitwise_portable)
fi
serve_tests="$(cargo test -q --test serve -- --list 2>/dev/null || true)"
for required in "${serve_required[@]}"; do
    if ! grep -q "$required" <<<"$serve_tests"; then
        echo "ci.sh: serving test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== warm-start suite present =="
# fit_from's contract: 0-epoch bit-identity with the prior, Lemma-2
# bit-identity warm, the appended-rows objective band, shrink refusal,
# and provenance-separated checkpoint lineage.
warmstart_required=(zero_epoch_fit_from_is_bit_identical_to_prior
    warm_threaded_equals_warm_replay_bitwise
    appended_rows_warm_start_stays_in_cold_objective_band
    shrinking_prior_is_refused
    warm_provenance_separates_checkpoint_lineage)
warmstart_tests="$(cargo test -q --test warmstart -- --list 2>/dev/null || true)"
for required in "${warmstart_required[@]}"; do
    if ! grep -q "$required" <<<"$warmstart_tests"; then
        echo "ci.sh: warm-start test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== step-rule suite present =="
# The adaptive rule's acceptance: convergence, accumulator shipping
# (threaded ≡ replay), the AdaGrad objective band, and admissibility
# across the async engine and baselines.
steprule_required=(adaptive_rule_converges_on_synthetic
    adaptive_threaded_equals_replay_bitwise
    adaptive_tracks_adagrad_objective_band
    async_and_baselines_accept_adaptive)
steprule_tests="$(cargo test -q --test steprule -- --list 2>/dev/null || true)"
for required in "${steprule_required[@]}"; do
    if ! grep -q "$required" <<<"$steprule_tests"; then
        echo "ci.sh: step-rule test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== mmap/madvise syscalls confined to data/cache/mmap.rs =="
# The arena is the single owner of every mapping: engines, kernels and
# transport see mapped tables only through BlockStore's slice surface.
# Comment lines are exempt (doc text may *describe* the mmap design).
if grep -rn "\bmmap(\|\bmunmap(\|\bmadvise(" rust/src --include="*.rs" \
    | grep -v "^rust/src/data/cache/" \
    | grep -v ":[[:space:]]*//"; then
    echo "ci.sh: raw mapping syscalls outside rust/src/data/cache/;" \
         "go through BlockStore / CacheHandle instead" >&2
    exit 1
fi

echo "== socket paths never bare-unwrap at all =="
# The real-transport layer must degrade, not panic: a corrupt frame, a
# dead peer, or a half-closed socket is routine input there. Non-test
# code in wire framing, FrameConn, and the supervisor must surface
# every failure as a Result/event (`let _ =` is the idiom for sends
# whose failure the reconnect path already covers).
socket_unwrap_gate() {
    awk '
        /#\[cfg\(test\)\]/ { exit bad }
        /\.unwrap\(\)|\.expect\(/ {
            printf "%s:%d: bare unwrap/expect on a transport path\n", FILENAME, FNR
            bad = 1
        }
        END { exit bad }
    ' "$1"
}
for f in rust/src/net/transport.rs rust/src/net/supervisor.rs rust/src/serve/server.rs; do
    if ! socket_unwrap_gate "$f"; then
        echo "ci.sh: surface the failure as a Result/event in $f" >&2
        exit 1
    fi
done

echo "== engine/net recovery paths never bare-unwrap a lock or join =="
# Fault tolerance dies the day a poisoned mutex or a worker join can
# panic the coordinator. Non-test code on the recovery paths must route
# through net::lock_tolerant / PoisonError::into_inner / WorkerFailure
# instead of .unwrap()/.expect() on lock, join, or into_inner results
# (the *_or_else recovery forms do not trip this gate).
unwrap_gate() {
    awk '
        /#\[cfg\(test\)\]/ { exit bad }
        /\.lock\(\)\.unwrap\(\)|\.join\(\)\.unwrap\(\)|\.join\(\)\.expect\(|into_inner\(\)\.unwrap\(\)/ {
            printf "%s:%d: bare unwrap on a lock/join in a recovery path\n", FILENAME, FNR
            bad = 1
        }
        END { exit bad }
    ' "$1"
}
for f in rust/src/coordinator/engine.rs rust/src/coordinator/async_engine.rs \
    rust/src/net/router.rs rust/src/net/faults.rs rust/src/net/mod.rs; do
    if ! unwrap_gate "$f"; then
        echo "ci.sh: route the failure through lock_tolerant/WorkerFailure in $f" >&2
        exit 1
    fi
done

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== bench smoke (quick mode) =="
    DSO_BENCH_QUICK=1 DSO_BENCH_JSON=1 cargo bench --bench bench_updates
    DSO_BENCH_QUICK=1 DSO_BENCH_JSON=1 cargo bench --bench bench_outofcore
    DSO_BENCH_QUICK=1 DSO_BENCH_JSON=1 cargo bench --bench bench_predict
    for f in BENCH_updates.json BENCH_lanes.json BENCH_alpha_lanes.json BENCH_simd.json \
        BENCH_autotune.json BENCH_faults.json BENCH_transport.json \
        BENCH_outofcore.json BENCH_predict.json BENCH_steprule.json; do
        if [[ -f "$f" ]]; then
            echo "recorded $f"
        else
            echo "ci.sh: bench smoke did not record $f" >&2
            exit 1
        fi
    done
    # On AVX-512 hosts the backend set must include the avx512 pair —
    # a silently missing entry would hide a broken guard.
    if grep -q avx512f /proc/cpuinfo 2>/dev/null; then
        for name in simd_avx512_hinge_adagrad simd_avx512_square_fixed; do
            if ! grep -q "$name" BENCH_simd.json; then
                echo "ci.sh: host supports avx512f but BENCH_simd.json lacks $name" >&2
                exit 1
            fi
        done
        if ! grep -q "autotune_avx512" BENCH_autotune.json; then
            echo "ci.sh: host supports avx512f but BENCH_autotune.json lacks autotune_avx512" >&2
            exit 1
        fi
    fi
fi

echo "ci.sh: all green"
