#!/usr/bin/env bash
# CI gate: format, lint, tests, and a quick-mode bench smoke that also
# records BENCH_updates.json, BENCH_lanes.json and BENCH_alpha_lanes.json
# (the cross-PR perf trajectory; plot with
# `python scripts/plot_results.py --bench`).
#
# Usage: scripts/ci.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH" >&2
    exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== kernel dispatch lives only in SweepPlan =="
# PR 4 moved the has_lanes()/affine_alpha() kernel-selection tree out
# of the engines into rust/src/coordinator/plan.rs. If dispatch logic
# leaks back into an engine, fail loudly: it is exactly the
# copy-paste drift this gate exists to prevent.
if grep -n "has_lanes\|affine_alpha" \
    rust/src/coordinator/engine.rs \
    rust/src/coordinator/async_engine.rs \
    rust/src/runtime/tile_engine.rs; then
    echo "ci.sh: kernel selection leaked back into an engine;" \
         "dispatch belongs in rust/src/coordinator/plan.rs" >&2
    exit 1
fi

echo "== cargo build --examples =="
# The five examples are the facade's public face; they must always
# compile against the current dso::api::Trainer surface.
cargo build --examples

echo "== lane kernel property suite present =="
# The SIMD sweep's correctness story rests on tests/lane_kernel.rs; if
# the suite is ever renamed, filtered out, or deleted, fail loudly
# instead of letting `cargo test` pass without it.
lane_tests="$(cargo test -q --test lane_kernel -- --list 2>/dev/null || true)"
for required in prop_lanes_match_scalar_oracle prop_sentinel_padding_never_perturbs_state \
    lanes_match_oracle_all_combinations_with_ragged_tails; do
    if ! grep -q "$required" <<<"$lane_tests"; then
        echo "ci.sh: lane kernel property test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== affine α-lane differential suite present =="
# Same guard for the square-loss affine-α path (tests/alpha_lane.rs):
# its tolerance-equivalence story rests on the differential suite.
alpha_tests="$(cargo test -q --test alpha_lane -- --list 2>/dev/null || true)"
for required in prop_affine_matches_coo_oracle prop_affine_sentinel_mutation_inert \
    affine_matches_oracle_ragged_and_short_groups \
    affine_long_row_stays_within_tolerance \
    affine_entry_point_is_bitwise_lane_kernel_for_nonaffine_losses \
    engine_affine_dispatch_threaded_equals_replay; do
    if ! grep -q "$required" <<<"$alpha_tests"; then
        echo "ci.sh: affine α-lane test '$required' missing/skipped" >&2
        exit 1
    fi
done

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== bench smoke (quick mode) =="
    DSO_BENCH_QUICK=1 DSO_BENCH_JSON=1 cargo bench --bench bench_updates
    for f in BENCH_updates.json BENCH_lanes.json BENCH_alpha_lanes.json; do
        if [[ -f "$f" ]]; then
            echo "recorded $f"
        else
            echo "ci.sh: bench smoke did not record $f" >&2
            exit 1
        fi
    done
fi

echo "ci.sh: all green"
