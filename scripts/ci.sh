#!/usr/bin/env bash
# CI gate: format, lint, tests, and a quick-mode bench smoke that also
# records BENCH_updates.json (the cross-PR perf trajectory).
#
# Usage: scripts/ci.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH" >&2
    exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== bench smoke (quick mode) =="
    DSO_BENCH_QUICK=1 DSO_BENCH_JSON=1 cargo bench --bench bench_updates
    if [[ -f BENCH_updates.json ]]; then
        echo "recorded BENCH_updates.json"
    fi
fi

echo "ci.sh: all green"
