#!/usr/bin/env python
"""Render the paper-style figures from the CSVs under results/.

Usage: python scripts/plot_results.py [results_dir] [out_dir]

Each experiment directory (fig2, fig3, fig4, fig5, ablation, sweeps)
contains one history CSV per algorithm/setting with the columns
epoch, virtual_s, wall_s, primal, dual, gap, test_error, updates,
comm_bytes. This script draws the paper's two standard panels per
experiment — objective vs. iterations and objective vs. time — plus
test-error panels where recorded. Degrades gracefully (text summary)
when matplotlib is unavailable.
"""

import csv
import os
import sys


def read_csv(path):
    with open(path) as f:
        rows = list(csv.reader(f))
    header = rows[0]
    cols = {name: [] for name in header}
    for row in rows[1:]:
        for name, val in zip(header, row):
            try:
                cols[name].append(float(val))
            except ValueError:
                cols[name].append(float("nan"))
    return cols


def series_in(exp_dir):
    out = {}
    for fn in sorted(os.listdir(exp_dir)):
        if fn.endswith(".csv"):
            out[fn[:-4]] = read_csv(os.path.join(exp_dir, fn))
    return out


def text_summary(exp, series):
    print(f"\n== {exp} ==")
    for label, cols in series.items():
        if not cols.get("primal"):
            continue
        print(
            f"  {label:<24} epochs={len(cols['primal']):>4} "
            f"objective {cols['primal'][0]:.4f} -> {cols['primal'][-1]:.4f}  "
            f"gap -> {cols['gap'][-1]:.3e}"
        )


def plot(exp, series, out_dir, plt):
    fig, axes = plt.subplots(1, 2, figsize=(11, 4))
    for label, cols in series.items():
        if not cols.get("primal"):
            continue
        axes[0].plot(cols["epoch"], cols["primal"], label=label, marker=".")
        axes[1].plot(cols["virtual_s"], cols["primal"], label=label, marker=".")
    axes[0].set_xlabel("iterations (epochs)")
    axes[1].set_xlabel("simulated cluster seconds")
    for ax in axes:
        ax.set_ylabel("objective value")
        ax.legend(fontsize=8)
        ax.set_title(exp)
    fig.tight_layout()
    path = os.path.join(out_dir, f"{exp.replace('/', '_')}.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    print(f"wrote {path}")


def main():
    results = sys.argv[1] if len(sys.argv) > 1 else "results"
    out_dir = sys.argv[2] if len(sys.argv) > 2 else os.path.join(results, "plots")
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        plt = None
        print("matplotlib not available — text summaries only")

    if plt is not None:
        os.makedirs(out_dir, exist_ok=True)

    for exp in sorted(os.listdir(results)):
        exp_dir = os.path.join(results, exp)
        if not os.path.isdir(exp_dir) or exp in ("plots", "bench"):
            continue
        # Sweep directories nest one level deeper.
        subdirs = [
            d for d in sorted(os.listdir(exp_dir))
            if os.path.isdir(os.path.join(exp_dir, d))
        ]
        targets = (
            [(f"{exp}/{d}", os.path.join(exp_dir, d)) for d in subdirs]
            if subdirs
            else [(exp, exp_dir)]
        )
        for name, d in targets:
            series = series_in(d)
            if not series:
                continue
            text_summary(name, series)
            if plt is not None:
                plot(name, series, out_dir, plt)


if __name__ == "__main__":
    main()
