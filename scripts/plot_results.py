#!/usr/bin/env python
"""Render the paper-style figures from the CSVs under results/, and the
cross-PR benchmark trajectory from BENCH_*.json files.

Usage:
    python scripts/plot_results.py [results_dir] [out_dir]
    python scripts/plot_results.py --bench [path ...] [--out out_dir]

Default mode — each experiment directory (fig2, fig3, fig4, fig5,
ablation, sweeps) contains one history CSV per algorithm/setting with
the columns epoch, virtual_s, wall_s, primal, dual, gap, test_error,
updates, comm_bytes, failures, wait_s. This script draws the paper's
two standard panels per experiment — objective vs. iterations and
objective vs. time. When any series recorded worker failures or
bounded-wait time (the fault-tolerance columns the async and
multi-process engines fill in), a second row of panels charts them —
so a chaos run's degradation is visible next to its convergence.

Bench mode (`--bench`) — each `path` is either a BENCH_<group>.json
file (as written by the Rust bench harness under DSO_BENCH_JSON=1), or
a directory scanned for them. A directory's immediate subdirectories
are treated as one snapshot each (named by the subdirectory — the
cross-PR convention is `bench_history/<pr-tag>/BENCH_*.json`); loose
BENCH_*.json in the directory itself form the "current" snapshot. For
every (group, benchmark) series the script prints units/sec across
snapshots and, with matplotlib, plots one trajectory panel per group.

The group set is open-ended and keyed by each file's own "group"
field, so snapshots from different PRs may carry different groups
(updates/lanes from PR 2, alpha_lanes from PR 3, simd from PR 5,
runtime's empty non-xla stub, ...) in any directory order; series
missing from a snapshot simply skip that tick.

Both modes degrade gracefully (text summary) when matplotlib is
unavailable.
"""

import csv
import json
import math
import os
import sys


def read_csv(path):
    with open(path) as f:
        rows = list(csv.reader(f))
    header = rows[0]
    cols = {name: [] for name in header}
    for row in rows[1:]:
        for name, val in zip(header, row):
            try:
                cols[name].append(float(val))
            except ValueError:
                cols[name].append(float("nan"))
    return cols


def series_in(exp_dir):
    out = {}
    for fn in sorted(os.listdir(exp_dir)):
        if fn.endswith(".csv"):
            out[fn[:-4]] = read_csv(os.path.join(exp_dir, fn))
    return out


def fault_columns_recorded(series):
    """True when any series carries a nonzero failures or wait_s value
    (NaN-safe: older CSVs without the columns simply don't chart)."""
    for cols in series.values():
        for key in ("failures", "wait_s"):
            if any(v > 0 for v in cols.get(key, []) if v == v):
                return True
    return False


def text_summary(exp, series):
    print(f"\n== {exp} ==")
    for label, cols in series.items():
        if not cols.get("primal"):
            continue
        line = (
            f"  {label:<24} epochs={len(cols['primal']):>4} "
            f"objective {cols['primal'][0]:.4f} -> {cols['primal'][-1]:.4f}  "
            f"gap -> {cols['gap'][-1]:.3e}"
        )
        failures = [v for v in cols.get("failures", []) if v == v]
        wait = [v for v in cols.get("wait_s", []) if v == v]
        if failures and failures[-1] > 0:
            line += f"  failures={int(failures[-1])}"
        if wait and wait[-1] > 0:
            line += f"  wait={wait[-1]:.3f}s"
        print(line)


def plot(exp, series, out_dir, plt):
    with_faults = fault_columns_recorded(series)
    if with_faults:
        fig, all_axes = plt.subplots(2, 2, figsize=(11, 8))
        axes, fault_axes = all_axes[0], all_axes[1]
    else:
        fig, axes = plt.subplots(1, 2, figsize=(11, 4))
        fault_axes = None
    for label, cols in series.items():
        if not cols.get("primal"):
            continue
        axes[0].plot(cols["epoch"], cols["primal"], label=label, marker=".")
        axes[1].plot(cols["virtual_s"], cols["primal"], label=label, marker=".")
        if fault_axes is not None:
            if cols.get("failures"):
                fault_axes[0].plot(
                    cols["epoch"], cols["failures"], label=label, marker="."
                )
            if cols.get("wait_s"):
                fault_axes[1].plot(
                    cols["epoch"], cols["wait_s"], label=label, marker="."
                )
    axes[0].set_xlabel("iterations (epochs)")
    axes[1].set_xlabel("simulated cluster seconds")
    for ax in axes:
        ax.set_ylabel("objective value")
        ax.legend(fontsize=8)
        ax.set_title(exp)
    if fault_axes is not None:
        fault_axes[0].set_ylabel("cumulative worker failures")
        fault_axes[1].set_ylabel("bounded-wait seconds")
        for ax in fault_axes:
            ax.set_xlabel("iterations (epochs)")
            ax.legend(fontsize=8)
    fig.tight_layout()
    path = os.path.join(out_dir, f"{exp.replace('/', '_')}.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    print(f"wrote {path}")


# ---------------------------------------------------------------------
# Bench trajectory mode
# ---------------------------------------------------------------------


def load_bench_file(path):
    """Parse one BENCH_<group>.json →
    (group, {name: units_per_sec}, {name: median_s_per_iter})."""
    with open(path) as f:
        doc = json.load(f)
    # Prefer the file's own group key; fall back to the filename stem
    # only when it matches the BENCH_<group>.json convention.
    base = os.path.basename(path)
    stem = base[len("BENCH_") : -len(".json")] if base.startswith("BENCH_") else base
    group = doc.get("group") or stem
    rates = {}
    latencies = {}
    for r in doc.get("results", []):
        name = r.get("name")
        ups = r.get("units_per_sec")
        if ups is None:
            median = r.get("median_s_per_iter") or 0.0
            ups = (r.get("units_per_iter") or 1) / median if median else 0.0
        if name:
            rates[name] = float(ups)
            latencies[name] = float(r.get("median_s_per_iter") or 0.0)
    return group, rates, latencies


def bench_files_in(directory):
    return sorted(
        os.path.join(directory, fn)
        for fn in os.listdir(directory)
        if fn.startswith("BENCH_") and fn.endswith(".json")
    )


def natural_key(s):
    """Sort embedded numbers numerically so pr10 follows pr2."""
    import re

    return [int(t) if t.isdigit() else t for t in re.split(r"(\d+)", s)]


def collect_snapshots(paths):
    """Return [(tag, [json paths])] in chronological presentation order:
    historical subdir snapshots first (natural-sorted, so pr2 < pr10),
    then any loose BENCH_*.json as the trailing "current" snapshot —
    ratios and plots read oldest → newest."""
    snapshots = []
    current = []
    for p in paths:
        if os.path.isfile(p):
            snapshots.append((os.path.basename(os.path.dirname(p)) or "current", [p]))
            continue
        if not os.path.isdir(p):
            print(f"bench: skipping {p} (not found)")
            continue
        for sub in sorted(os.listdir(p), key=natural_key):
            subdir = os.path.join(p, sub)
            if os.path.isdir(subdir):
                files = bench_files_in(subdir)
                if files:
                    snapshots.append((sub, files))
        current.extend(bench_files_in(p))
    if current:
        snapshots.append(("current", current))
    return snapshots


BACKENDS = ("portable", "avx2", "avx512")


def backend_throughput(trajectory, tags):
    """Fold per-backend bench series into one throughput trajectory per
    SIMD backend: {backend: {tag: units_per_sec}}.

    Sources: the `simd` group's `simd_<backend>_<loss>_<rule>` kernels
    and the `autotune` group's `autotune_<backend>` probe reps. Within a
    (backend, tag) cell the entries are averaged geometrically so no
    single loss/rule combination dominates. Backends with no entries at
    all (e.g. avx512 on hosts without AVX-512) simply produce no series
    — absence is expected, not an error."""
    cells = {}  # backend -> tag -> [ups, ...]
    for group, prefix in (("simd", "simd_"), ("autotune", "autotune_")):
        for name, by_tag in trajectory.get(group, {}).items():
            if not name.startswith(prefix):
                continue
            rest = name[len(prefix) :]
            backend = next(
                (b for b in BACKENDS if rest == b or rest.startswith(b + "_")),
                None,
            )
            if backend is None:
                continue  # e.g. autotune_resolve_<winner> marker rows
            for tag, ups in by_tag.items():
                if ups > 0:
                    cells.setdefault(backend, {}).setdefault(tag, []).append(ups)
    out = {}
    for backend, by_tag in cells.items():
        series = {}
        for tag in tags:
            vals = by_tag.get(tag)
            if vals:
                series[tag] = math.exp(sum(math.log(v) for v in vals) / len(vals))
        if series:
            out[backend] = series
    return out


def bench_mode(paths, out_dir, plt):
    snapshots = collect_snapshots(paths or ["."])
    if not snapshots:
        print("bench: no BENCH_*.json found")
        return 1
    # One shared x-axis of snapshot tags, in collection order, so a
    # series that is missing from some snapshots (added, renamed, or
    # filtered between PRs) still lands on the right tick.
    tags = []
    # trajectory[group][bench_name] = {tag: units_per_sec}
    trajectory = {}
    # latency[group][bench_name] = {tag: median_s_per_iter} — the
    # serving panel charts the predict group's per-batch latency, the
    # quantity a model server actually budgets.
    latency = {}
    for tag, files in snapshots:
        if tag not in tags:
            tags.append(tag)
        for path in files:
            group, rates, lats = load_bench_file(path)
            # Register the group even when it recorded no results (e.g.
            # bench_runtime's non-xla stub) so a run-and-skipped group
            # is visible rather than a silent gap.
            trajectory.setdefault(group, {})
            for name, ups in rates.items():
                trajectory.setdefault(group, {}).setdefault(name, {})[tag] = ups
            for name, lat in lats.items():
                if lat > 0:
                    latency.setdefault(group, {}).setdefault(name, {})[tag] = lat

    for group in sorted(trajectory):
        print(f"\n== bench group: {group} (units/sec) ==")
        if not trajectory[group]:
            print("  (no results recorded — group ran but was skipped)")
            continue
        for name in sorted(trajectory[group]):
            by_tag = trajectory[group][name]
            pts = [(t, by_tag[t]) for t in tags if t in by_tag]
            path_txt = "  ".join(f"{tag}:{ups:.3e}" for tag, ups in pts)
            if len(pts) >= 2 and pts[0][1] > 0:
                path_txt += f"  [{pts[-1][1] / pts[0][1]:.2f}x vs {pts[0][0]}]"
            print(f"  {name:<40} {path_txt}")
        if group == "predict" and latency.get("predict"):
            print("  -- median batch latency (ms, lower is better) --")
            for name in sorted(latency["predict"]):
                by_tag = latency["predict"][name]
                pts = [(t, by_tag[t] * 1e3) for t in tags if t in by_tag]
                print(f"  {name:<40} " + "  ".join(f"{t}:{v:.3f}" for t, v in pts))

    # Per-backend throughput rollup: geometric mean of every sweep
    # kernel (simd group) and autotune probe rep for each SIMD backend.
    # Backends absent from the snapshots (avx512 on non-AVX-512 hosts)
    # are simply not listed.
    backends = backend_throughput(trajectory, tags)
    if backends:
        print("\n== simd backend throughput (geomean units/sec) ==")
        for backend in BACKENDS:
            by_tag = backends.get(backend)
            if not by_tag:
                continue
            pts = [(t, by_tag[t]) for t in tags if t in by_tag]
            path_txt = "  ".join(f"{tag}:{ups:.3e}" for tag, ups in pts)
            print(f"  {backend:<40} {path_txt}")

    if plt is None:
        return 0
    os.makedirs(out_dir, exist_ok=True)
    for group, names in sorted(trajectory.items()):
        if not names:
            continue
        fig, ax = plt.subplots(figsize=(8, 4.5))
        for name, by_tag in sorted(names.items()):
            xs = [i for i, t in enumerate(tags) if t in by_tag]
            ys = [by_tag[tags[i]] for i in xs]
            ax.plot(xs, ys, label=name, marker="o")
        ax.set_xticks(range(len(tags)))
        ax.set_xticklabels(tags, rotation=30, ha="right", fontsize=8)
        ax.set_ylabel("units / second")
        ax.set_yscale("log")
        ax.set_title(f"bench trajectory: {group}")
        ax.legend(fontsize=7)
        fig.tight_layout()
        path = os.path.join(out_dir, f"bench_{group}.png")
        fig.savefig(path, dpi=120)
        plt.close(fig)
        print(f"wrote {path}")

    # Dedicated predict-latency panel: median seconds per batch for the
    # serving kernels (scalar loop vs batched portable vs batched AVX2),
    # across snapshots — lower is better, unlike the units/sec panels.
    if "predict" in latency and latency["predict"]:
        fig, ax = plt.subplots(figsize=(8, 4.5))
        for name, by_tag in sorted(latency["predict"].items()):
            xs = [i for i, t in enumerate(tags) if t in by_tag]
            ys = [by_tag[tags[i]] * 1e3 for i in xs]
            ax.plot(xs, ys, label=name, marker="o")
        ax.set_xticks(range(len(tags)))
        ax.set_xticklabels(tags, rotation=30, ha="right", fontsize=8)
        ax.set_ylabel("median batch latency (ms)")
        ax.set_yscale("log")
        ax.set_title("serving: predict latency per batch (lower is better)")
        ax.legend(fontsize=7)
        fig.tight_layout()
        path = os.path.join(out_dir, "bench_predict_latency.png")
        fig.savefig(path, dpi=120)
        plt.close(fig)
        print(f"wrote {path}")

    # Backend-throughput panel: one line per SIMD backend (portable /
    # avx2 / avx512), geometric mean across that backend's sweep kernels
    # and autotune probe reps. A backend with no recorded entries in any
    # snapshot — avx512 on hosts without AVX-512 — contributes no line.
    if backends:
        fig, ax = plt.subplots(figsize=(8, 4.5))
        for backend in BACKENDS:
            by_tag = backends.get(backend)
            if not by_tag:
                continue
            xs = [i for i, t in enumerate(tags) if t in by_tag]
            ys = [by_tag[tags[i]] for i in xs]
            ax.plot(xs, ys, label=backend, marker="o")
        ax.set_xticks(range(len(tags)))
        ax.set_xticklabels(tags, rotation=30, ha="right", fontsize=8)
        ax.set_ylabel("geomean units / second")
        ax.set_yscale("log")
        ax.set_title("simd backend throughput (sweep kernels + autotune probe)")
        ax.legend(fontsize=8)
        fig.tight_layout()
        path = os.path.join(out_dir, "bench_backend_throughput.png")
        fig.savefig(path, dpi=120)
        plt.close(fig)
        print(f"wrote {path}")
    return 0


def import_matplotlib():
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt

        return plt
    except ImportError:
        print("matplotlib not available — text summaries only")
        return None


def main():
    args = sys.argv[1:]
    if args and args[0] == "--bench":
        rest = args[1:]
        out_dir = "results/plots"
        if "--out" in rest:
            i = rest.index("--out")
            if i + 1 >= len(rest):
                print("usage: plot_results.py --bench [path ...] [--out out_dir]")
                sys.exit(2)
            out_dir = rest[i + 1]
            rest = rest[:i] + rest[i + 2 :]
        sys.exit(bench_mode(rest, out_dir, import_matplotlib()))

    results = args[0] if len(args) > 0 else "results"
    out_dir = args[1] if len(args) > 1 else os.path.join(results, "plots")
    plt = import_matplotlib()

    if plt is not None:
        os.makedirs(out_dir, exist_ok=True)

    for exp in sorted(os.listdir(results)):
        exp_dir = os.path.join(results, exp)
        if not os.path.isdir(exp_dir) or exp in ("plots", "bench"):
            continue
        # Sweep directories nest one level deeper.
        subdirs = [
            d for d in sorted(os.listdir(exp_dir))
            if os.path.isdir(os.path.join(exp_dir, d))
        ]
        targets = (
            [(f"{exp}/{d}", os.path.join(exp_dir, d)) for d in subdirs]
            if subdirs
            else [(exp, exp_dir)]
        )
        for name, d in targets:
            series = series_in(d)
            if not series:
                continue
            text_summary(name, series)
            if plt is not None:
                plot(name, series, out_dir, plt)


if __name__ == "__main__":
    main()
